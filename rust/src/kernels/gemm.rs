//! The four GEMM shapes as packed, register-tiled micro-kernel drivers.
//!
//! Every shape is canonicalized onto the same machinery: the streaming
//! operand is packed once into `NR`-wide column panels, each worker packs
//! `MR`-row tiles of the broadcast operand, and [`super::micro::tile`]
//! computes `MR × NR` output blocks with all accumulators in registers
//! ([`super::pack`] documents the layouts). Each accumulator lane is one
//! output element summed in ascending reduction order with separately
//! rounded mul/add, so results are **bit-identical to the naive triple
//! loop** ([`super::reference`]) for *every* input — signed zeros,
//! subnormals, infinities and NaNs included — and independent of both the
//! thread count and the SIMD/scalar dispatch decision.
//!
//! The historical `av == 0.0` zero-skip fast paths are gone: they matched
//! `-0.0` and dropped `0·±inf` / `0·NaN` products, silently violating
//! that contract (the regression tests below pin the repaired semantics).

use super::pack::{MR, NR};
use super::{configured_threads, for_each_row_chunk, micro, pack};

/// `A (m,k) @ B (k,n)` with the configured worker count.
pub fn gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    gemm_with_threads(a, b, m, k, n, configured_threads())
}

/// `A (m,k) @ B (k,n)` on an explicit worker count (output rows are
/// partitioned; reduction order is fixed, so results do not depend on
/// `threads`).
pub fn gemm_with_threads(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) -> Vec<f32> {
    gemm_with_dispatch(a, b, m, k, n, threads, micro::simd_enabled())
}

/// [`gemm_with_threads`] with an explicit SIMD/scalar dispatch decision
/// (`simd: true` silently falls back to the portable tile on CPUs
/// without the feature). Both paths are bit-identical by contract; this
/// entry point exists so tests and benches can pin either side.
pub fn gemm_with_dispatch(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
    simd: bool,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k, "gemm: A shape");
    debug_assert_eq!(b.len(), k * n, "gemm: B shape");
    let mut out = vec![0.0f32; m * n];
    if m == 0 || n == 0 || k == 0 {
        // empty reduction: the reference stores an explicit +0.0
        return out;
    }
    let pb = pack::pack_b_panels(b, n, n, k);
    for_each_row_chunk(&mut out, n, threads, 2 * m * k * n, |row0, chunk| {
        panel_tiles(
            |r0, nrows, buf| pack::pack_a_rows(a, k, row0 + r0, nrows, k, buf),
            k,
            &pb,
            n,
            chunk,
            simd,
        );
    });
    out
}

/// `A (m,k) @ Bᵀ` with `B (n,k)` — row-dot products.
pub fn gemm_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    gemm_nt_with_threads(a, b, m, k, n, configured_threads())
}

/// `A (m,k) @ Bᵀ` with `B (n,k)` on an explicit worker count.
pub fn gemm_nt_with_threads(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) -> Vec<f32> {
    gemm_nt_with_dispatch(a, b, m, k, n, threads, micro::simd_enabled())
}

/// [`gemm_nt_with_threads`] with an explicit SIMD/scalar dispatch
/// decision. `B` is packed transposed (`pack_bt_panels`), after which
/// the driver is exactly [`gemm_with_dispatch`]'s — the ascending-`k`
/// walk over the packed panel reproduces the naive row-dot reduction
/// order bit-for-bit.
pub fn gemm_nt_with_dispatch(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
    simd: bool,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k, "gemm_nt: A shape");
    debug_assert_eq!(b.len(), n * k, "gemm_nt: B shape");
    let mut out = vec![0.0f32; m * n];
    if m == 0 || n == 0 || k == 0 {
        // k == 0 is every row-dot over zero terms: the reference stores
        // an explicit `s = +0.0` per element, which the pre-zeroed
        // output reproduces exactly (regression-tested below).
        return out;
    }
    let pbt = pack::pack_bt_panels(b, n, k);
    for_each_row_chunk(&mut out, n, threads, 2 * m * k * n, |row0, chunk| {
        panel_tiles(
            |r0, nrows, buf| pack::pack_a_rows(a, k, row0 + r0, nrows, k, buf),
            k,
            &pbt,
            n,
            chunk,
            simd,
        );
    });
    out
}

/// `A[:, :lim]ᵀ @ B` with `A (rows, ka)`, `B (rows, kb)` → `(lim, kb)`.
///
/// The S²FT row-split partial-backprop kernel: with `lim < ka` only the
/// trainable slice of the weight gradient is ever materialized — the
/// activation is sliced *before* the GEMM (paper §3.3).
pub fn gemm_tn(a: &[f32], b: &[f32], rows: usize, ka: usize, kb: usize, lim: usize) -> Vec<f32> {
    gemm_tn_with_threads(a, b, rows, ka, kb, lim, configured_threads())
}

/// [`gemm_tn`] on an explicit worker count (output rows partitioned).
#[allow(clippy::too_many_arguments)]
pub fn gemm_tn_with_threads(
    a: &[f32],
    b: &[f32],
    rows: usize,
    ka: usize,
    kb: usize,
    lim: usize,
    threads: usize,
) -> Vec<f32> {
    gemm_tn_with_dispatch(a, b, rows, ka, kb, lim, threads, micro::simd_enabled())
}

/// [`gemm_tn_with_threads`] with an explicit SIMD/scalar dispatch
/// decision. The broadcast operand is `A`'s leading columns (packed via
/// `pack_a_cols`); the reduction walks `rows` ascending.
#[allow(clippy::too_many_arguments)]
pub fn gemm_tn_with_dispatch(
    a: &[f32],
    b: &[f32],
    rows: usize,
    ka: usize,
    kb: usize,
    lim: usize,
    threads: usize,
    simd: bool,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), rows * ka, "gemm_tn: A shape");
    debug_assert_eq!(b.len(), rows * kb, "gemm_tn: B shape");
    debug_assert!(lim <= ka, "gemm_tn: lim {lim} > ka {ka}");
    let mut out = vec![0.0f32; lim * kb];
    if lim == 0 || kb == 0 || rows == 0 {
        return out; // empty output or empty reduction (explicit +0.0)
    }
    let pb = pack::pack_b_panels(b, kb, kb, rows);
    for_each_row_chunk(&mut out, kb, threads, 2 * rows * lim * kb, |i0, chunk| {
        panel_tiles(
            |r0, ncols, buf| pack::pack_a_cols(a, ka, i0 + r0, ncols, rows, buf),
            rows,
            &pb,
            kb,
            chunk,
            simd,
        );
    });
    out
}

/// `Aᵀ @ B[:, :lim]` with `A (rows, ka)`, `B (rows, kb)` → `(ka, lim)` —
/// the column-split partial gradient (trainable head/channel columns).
pub fn gemm_tn_outcols(
    a: &[f32],
    b: &[f32],
    rows: usize,
    ka: usize,
    kb: usize,
    lim: usize,
) -> Vec<f32> {
    gemm_tn_outcols_with_threads(a, b, rows, ka, kb, lim, configured_threads())
}

/// [`gemm_tn_outcols`] on an explicit worker count.
#[allow(clippy::too_many_arguments)]
pub fn gemm_tn_outcols_with_threads(
    a: &[f32],
    b: &[f32],
    rows: usize,
    ka: usize,
    kb: usize,
    lim: usize,
    threads: usize,
) -> Vec<f32> {
    gemm_tn_outcols_with_dispatch(a, b, rows, ka, kb, lim, threads, micro::simd_enabled())
}

/// [`gemm_tn_outcols_with_threads`] with an explicit SIMD/scalar dispatch
/// decision. Only `B`'s leading `lim` columns are packed, so the panel
/// pass never touches frozen columns.
#[allow(clippy::too_many_arguments)]
pub fn gemm_tn_outcols_with_dispatch(
    a: &[f32],
    b: &[f32],
    rows: usize,
    ka: usize,
    kb: usize,
    lim: usize,
    threads: usize,
    simd: bool,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), rows * ka, "gemm_tn_outcols: A shape");
    debug_assert_eq!(b.len(), rows * kb, "gemm_tn_outcols: B shape");
    debug_assert!(lim <= kb, "gemm_tn_outcols: lim {lim} > kb {kb}");
    let mut out = vec![0.0f32; ka * lim];
    if ka == 0 || lim == 0 || rows == 0 {
        return out; // empty output or empty reduction (explicit +0.0)
    }
    let pb = pack::pack_b_panels(b, kb, lim, rows);
    for_each_row_chunk(&mut out, lim, threads, 2 * rows * ka * lim, |i0, chunk| {
        panel_tiles(
            |r0, ncols, buf| pack::pack_a_cols(a, ka, i0 + r0, ncols, rows, buf),
            rows,
            &pb,
            lim,
            chunk,
            simd,
        );
    });
    out
}

/// Drive the micro-kernel over one worker's output rows: pack an
/// `MR`-wide tile of the broadcast operand (`pack_tile(first_local_row,
/// nrows, buf)` fills a `depth * MR` panel), sweep the pre-packed B
/// panels, and copy the valid `nrows × w` window of each register tile
/// into `out`. Padded lanes are computed and discarded.
fn panel_tiles<F: Fn(usize, usize, &mut [f32])>(
    pack_tile: F,
    depth: usize,
    pb: &[f32],
    row_len: usize,
    out: &mut [f32],
    simd: bool,
) {
    let rows = out.len() / row_len;
    let mut pa = vec![0.0f32; depth * MR];
    let mut acc = [[0.0f32; NR]; MR];
    let mut r0 = 0;
    while r0 < rows {
        let tr = MR.min(rows - r0);
        pack_tile(r0, tr, &mut pa);
        for (jp, pbp) in pb.chunks_exact(depth * NR).enumerate() {
            let j0 = jp * NR;
            let w = NR.min(row_len - j0);
            micro::tile(&pa, pbp, &mut acc, simd);
            for (rr, arow) in acc.iter().enumerate().take(tr) {
                out[(r0 + rr) * row_len + j0..][..w].copy_from_slice(&arow[..w]);
            }
        }
        r0 += MR;
    }
}

/// Sliced-cache copy: the first `lim` columns of each row of `A (rows,
/// cols)`, packed into a `(rows, lim)` buffer.
///
/// This is the cache-time half of the S²FT partial-gradient contract:
/// the trainable-first co-permutation puts the trainable channels first,
/// so retaining `A[:, :lim]` at forward time is enough to later compute
/// `gemm_tn(sliced, dY, rows, lim, kb, lim)` — bit-identical to
/// `gemm_tn(full, dY, rows, cols, kb, lim)`, but the frozen channels are
/// never held across the forward/backward gap.
pub fn slice_cols(a: &[f32], rows: usize, cols: usize, lim: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), rows * cols, "slice_cols: A shape");
    debug_assert!(lim <= cols, "slice_cols: lim {lim} > cols {cols}");
    let mut out = vec![0.0f32; rows * lim];
    for (r, orow) in out.chunks_exact_mut(lim.max(1)).enumerate() {
        orow.copy_from_slice(&a[r * cols..r * cols + lim]);
    }
    out
}

/// Fused GEMV accumulate: `y (n) += scale · (x (k) @ W (k,n))` on the
/// calling thread — the per-request adapter-delta shape (one activation
/// row against a small dense delta).
///
/// Accumulates straight into the caller's `y` in ascending `k` with no
/// zero-skip: the historical `v == 0.0 { continue }` left a caller-held
/// `-0.0` untouched where IEEE addition flips it to `+0.0`, and dropped
/// `0·NaN` products (regression-tested below).
pub fn gemv_acc(x: &[f32], w: &[f32], n: usize, scale: f32, y: &mut [f32]) {
    debug_assert_eq!(y.len(), n, "gemv_acc: y shape");
    debug_assert_eq!(w.len(), x.len() * n, "gemv_acc: W shape");
    for (kk, &xv) in x.iter().enumerate() {
        let v = xv * scale;
        let wrow = &w[kk * n..][..n];
        for (o, &wv) in y.iter_mut().zip(wrow) {
            *o += v * wv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::reference;
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    /// Bitwise equality, except any-NaN == any-NaN: IEEE 754 and LLVM
    /// leave NaN payload/sign propagation unspecified across differently
    /// compiled code, so tests assert *that* a NaN surfaces, not which.
    fn bits_eq_mod_nan(a: &[f32], b: &[f32]) -> bool {
        a.len() == b.len()
            && a.iter()
                .zip(b)
                .all(|(x, y)| x.to_bits() == y.to_bits() || (x.is_nan() && y.is_nan()))
    }

    #[test]
    fn gemm_known_values() {
        // [1 2; 3 4] @ [1 1; 1 1] = [3 3; 7 7]
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![1.0, 1.0, 1.0, 1.0];
        assert_eq!(gemm(&a, &b, 2, 2, 2), vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn gemm_quad_and_remainder_match_reference() {
        // rows/cols chosen to exercise full MR×NR tiles plus both padded
        // edges (row remainder and right-edge column panel)
        let mut rng = Rng::seed(11);
        for (m, k, n) in [(1, 3, 2), (4, 5, 6), (6, 7, 3), (9, 4, 8), (12, 1, 1), (5, 9, 35)] {
            let a = randv(&mut rng, m * k);
            let b = randv(&mut rng, k * n);
            assert_eq!(
                gemm_with_threads(&a, &b, m, k, n, 1),
                reference::gemm(&a, &b, m, k, n),
                "{m}x{k}x{n}"
            );
        }
    }

    #[test]
    fn gemm_nt_matches_reference() {
        let mut rng = Rng::seed(12);
        for (m, k, n) in [(5, 4, 3), (8, 6, 7), (3, 1, 9), (7, 5, 33)] {
            let a = randv(&mut rng, m * k);
            let b = randv(&mut rng, n * k);
            assert_eq!(
                gemm_nt_with_threads(&a, &b, m, k, n, 1),
                reference::gemm_nt(&a, &b, m, k, n)
            );
        }
    }

    #[test]
    fn gemm_nt_degenerate_k_stores_explicit_zeros() {
        // k == 0: every dot product is the empty sum. The reference
        // stores an explicit +0.0 per element; the kernel must produce
        // the same +0.0 bits rather than leaving rows unwritten.
        let out = gemm_nt_with_threads(&[], &[], 2, 0, 3, 1);
        assert_eq!(out.len(), 6);
        assert!(out.iter().all(|v| v.to_bits() == 0), "expected all +0.0 bits");
        assert_eq!(out, reference::gemm_nt(&[], &[], 2, 0, 3));
        // same contract for the plain-gemm degenerate shapes
        assert_eq!(gemm_with_threads(&[], &[], 2, 0, 3, 1), reference::gemm(&[], &[], 2, 0, 3));
    }

    #[test]
    fn gemm_tn_partial_equals_slice_of_full() {
        let mut rng = Rng::seed(13);
        let (rows, ka, kb) = (9, 7, 5);
        let a = randv(&mut rng, rows * ka);
        let b = randv(&mut rng, rows * kb);
        let full = gemm_tn(&a, &b, rows, ka, kb, ka);
        for lim in [0, 1, 3, ka] {
            let part = gemm_tn(&a, &b, rows, ka, kb, lim);
            assert_eq!(part, full[..lim * kb].to_vec(), "lim {lim}");
            assert_eq!(part, reference::gemm_tn(&a, &b, rows, ka, kb, lim));
        }
    }

    #[test]
    fn gemm_tn_outcols_partial_equals_cols_of_full() {
        let mut rng = Rng::seed(14);
        let (rows, ka, kb) = (8, 6, 7);
        let a = randv(&mut rng, rows * ka);
        let b = randv(&mut rng, rows * kb);
        let full = gemm_tn_outcols(&a, &b, rows, ka, kb, kb);
        for lim in [0, 2, 5, kb] {
            let part = gemm_tn_outcols(&a, &b, rows, ka, kb, lim);
            let want: Vec<f32> =
                (0..ka).flat_map(|i| full[i * kb..i * kb + lim].to_vec()).collect();
            assert_eq!(part, want, "lim {lim}");
            assert_eq!(part, reference::gemm_tn_outcols(&a, &b, rows, ka, kb, lim));
        }
    }

    #[test]
    fn slice_cols_keeps_leading_columns() {
        // (2,3) -> first 2 cols
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        assert_eq!(slice_cols(&a, 2, 3, 2), vec![1.0, 2.0, 4.0, 5.0]);
        assert_eq!(slice_cols(&a, 2, 3, 0), Vec::<f32>::new());
        assert_eq!(slice_cols(&a, 2, 3, 3), a);
    }

    #[test]
    fn gemm_tn_on_sliced_cache_is_bit_identical_to_gemm_time_slice() {
        // the cache-time slice contract: slicing A before the GEMM gives
        // the exact bits of the lim-limited GEMM over the full A
        let mut rng = Rng::seed(16);
        let (rows, ka, kb) = (11, 9, 6);
        let a = randv(&mut rng, rows * ka);
        let b = randv(&mut rng, rows * kb);
        for lim in [0usize, 1, 4, ka] {
            let at_gemm_time = gemm_tn(&a, &b, rows, ka, kb, lim);
            let sliced = slice_cols(&a, rows, ka, lim);
            let at_cache_time = gemm_tn(&sliced, &b, rows, lim, kb, lim);
            assert!(
                at_gemm_time
                    .iter()
                    .zip(&at_cache_time)
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                "lim {lim}"
            );
        }
    }

    #[test]
    fn gemv_acc_accumulates_scaled() {
        let x = vec![1.0, 0.0, 2.0];
        let w = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // (3,2)
        let mut y = vec![10.0, 20.0];
        gemv_acc(&x, &w, 2, 0.5, &mut y);
        // y += 0.5 * [1*[1,2] + 2*[5,6]] = [5.5, 7.0]
        assert_eq!(y, vec![15.5, 27.0]);
    }

    #[test]
    fn thread_counts_are_bit_identical() {
        let mut rng = Rng::seed(15);
        let (m, k, n) = (33, 40, 37); // above MIN_PAR_WORK
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let bt = randv(&mut rng, n * k);
        let one = gemm_with_threads(&a, &b, m, k, n, 1);
        let one_nt = gemm_nt_with_threads(&a, &bt, m, k, n, 1);
        for t in [2usize, 3, 5, 8] {
            let many = gemm_with_threads(&a, &b, m, k, n, t);
            assert!(one.iter().zip(&many).all(|(x, y)| x.to_bits() == y.to_bits()), "t={t}");
            let many_nt = gemm_nt_with_threads(&a, &bt, m, k, n, t);
            assert!(one_nt.iter().zip(&many_nt).all(|(x, y)| x.to_bits() == y.to_bits()));
        }
    }

    #[test]
    fn simd_and_scalar_dispatch_are_bit_identical() {
        let mut rng = Rng::seed(21);
        for (m, k, n) in [(7, 33, 18), (16, 16, 16), (5, 1, 40), (12, 20, 3)] {
            let a = randv(&mut rng, m * k);
            let b = randv(&mut rng, k * n);
            let bt = randv(&mut rng, n * k);
            assert_eq!(
                gemm_with_dispatch(&a, &b, m, k, n, 1, true),
                gemm_with_dispatch(&a, &b, m, k, n, 1, false),
                "gemm {m}x{k}x{n}"
            );
            assert_eq!(
                gemm_nt_with_dispatch(&a, &bt, m, k, n, 1, true),
                gemm_nt_with_dispatch(&a, &bt, m, k, n, 1, false),
                "gemm_nt {m}x{k}x{n}"
            );
            assert_eq!(
                gemm_tn_with_dispatch(&a, &a, m, k, k, k.min(5), 1, true),
                gemm_tn_with_dispatch(&a, &a, m, k, k, k.min(5), 1, false),
                "gemm_tn {m}x{k}"
            );
            assert_eq!(
                gemm_tn_outcols_with_dispatch(&a, &a, m, k, k, k.min(3), 1, true),
                gemm_tn_outcols_with_dispatch(&a, &a, m, k, k, k.min(3), 1, false),
                "gemm_tn_outcols {m}x{k}"
            );
        }
    }

    /// Pre-fix, `if av == 0.0 { continue }` dropped `0·inf = NaN` and
    /// `0·NaN = NaN` products (and matched `-0.0`): the output stayed
    /// `+0.0` where the naive reference propagates NaN. This test fails
    /// on the zero-skip code.
    #[test]
    fn gemm_zero_times_nonfinite_propagates_like_reference() {
        // b rows: [1, inf], [NaN, -2], [0.5, 1] — every output column 0
        // crosses the NaN row through a zero A value.
        let b = vec![1.0, f32::INFINITY, f32::NAN, -2.0, 0.5, 1.0];
        for m in [1usize, 4, 5] {
            // all-zero A rows (the quad/remainder skip trigger), with a
            // signed zero in row 0 for the `-0.0 == 0.0` variant
            let mut a = vec![0.0f32; m * 3];
            a[2] = -0.0;
            let got = gemm_with_threads(&a, &b, m, 3, 2, 1);
            let want = reference::gemm(&a, &b, m, 3, 2);
            assert!(want.iter().any(|v| v.is_nan()), "case must exercise NaN propagation");
            assert!(
                got.iter().any(|v| v.is_nan()),
                "m={m}: zero-skip regression — 0·NaN product was dropped"
            );
            assert!(bits_eq_mod_nan(&got, &want), "m={m}");
        }
    }

    /// Same contract for both partial-gradient kernels: a trainable
    /// column of exact zeros must still propagate `0·inf = NaN` from the
    /// upstream gradient. Fails on the pre-fix zero-skip code.
    #[test]
    fn gemm_tn_zero_times_nonfinite_propagates_like_reference() {
        // A (2,2) column 0 is [+0.0, -0.0]; B (2,1) holds [inf, 1]
        let a = vec![0.0, 3.0, -0.0, 4.0];
        let b = vec![f32::INFINITY, 1.0];
        let got = gemm_tn_with_threads(&a, &b, 2, 2, 1, 2, 1);
        let want = reference::gemm_tn(&a, &b, 2, 2, 1, 2);
        assert!(got[0].is_nan(), "0·inf dropped by gemm_tn");
        assert!(bits_eq_mod_nan(&got, &want));

        let gotc = gemm_tn_outcols_with_threads(&a, &b, 2, 2, 1, 1, 1);
        let wantc = reference::gemm_tn_outcols(&a, &b, 2, 2, 1, 1);
        assert!(gotc[0].is_nan(), "0·inf dropped by gemm_tn_outcols");
        assert!(bits_eq_mod_nan(&gotc, &wantc));
    }

    /// `gemv_acc` accumulates into caller-owned memory, so the zero-skip
    /// diverged on *finite* inputs too: IEEE says `-0.0 + (+0.0 · 1.0) =
    /// +0.0`, but skipping the zero product left `y = -0.0` untouched.
    /// Fails on the pre-fix zero-skip code.
    #[test]
    fn gemv_acc_zero_product_still_updates_accumulator() {
        let mut y = vec![-0.0f32];
        gemv_acc(&[0.0], &[1.0], 1, 1.0, &mut y);
        assert_eq!(y[0].to_bits(), 0.0f32.to_bits(), "-0.0 + 0.0 must flip to +0.0");

        let mut y2 = vec![0.0f32];
        gemv_acc(&[0.0], &[f32::NAN], 1, 1.0, &mut y2);
        assert!(y2[0].is_nan(), "0·NaN dropped by gemv_acc");

        // scale-induced zero products must reach the accumulator too
        let mut y3 = vec![-0.0f32, -0.0];
        gemv_acc(&[5.0], &[1.0, -1.0], 2, 0.0, &mut y3);
        assert_eq!(y3[0].to_bits(), 0.0f32.to_bits());
        assert_eq!(y3[1].to_bits(), (-0.0f32).to_bits(), "-0.0 + -0.0 stays -0.0");
    }
}
