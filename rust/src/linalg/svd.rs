//! One-sided Jacobi SVD.
//!
//! Robust and dependency-free; O(n³) per sweep which is fine at the theory
//! simulator's scale (dims ≤ a few hundred). For `rows < cols` we factor
//! the transpose and swap U/V.

use super::Mat;

pub struct Svd {
    /// (rows, k) left singular vectors, k = min(rows, cols).
    pub u: Mat,
    /// singular values, descending.
    pub s: Vec<f32>,
    /// (k, cols) right singular vectors (transposed).
    pub vt: Mat,
}

/// Compute the thin SVD `A = U diag(s) Vt`.
pub fn svd(a: &Mat) -> Svd {
    if a.rows < a.cols {
        let t = svd(&a.t());
        return Svd { u: t.vt.t(), s: t.s, vt: t.u.t() };
    }
    let m = a.rows;
    let n = a.cols;
    // Work on columns of U = A (will become U * diag(s)); V accumulates rotations.
    let mut u = a.clone();
    let mut v = Mat::eye(n);
    let eps = 1e-10f64;
    for _sweep in 0..60 {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // 2x2 Gram entries
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    let up = u[(i, p)] as f64;
                    let uq = u[(i, q)] as f64;
                    app += up * up;
                    aqq += uq * uq;
                    apq += up * uq;
                }
                off += apq * apq;
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                // Jacobi rotation zeroing the (p,q) Gram entry
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let up = u[(i, p)] as f64;
                    let uq = u[(i, q)] as f64;
                    u[(i, p)] = (c * up - s * uq) as f32;
                    u[(i, q)] = (s * up + c * uq) as f32;
                }
                for i in 0..n {
                    let vp = v[(i, p)] as f64;
                    let vq = v[(i, q)] as f64;
                    v[(i, p)] = (c * vp - s * vq) as f32;
                    v[(i, q)] = (s * vp + c * vq) as f32;
                }
            }
        }
        if off.sqrt() < 1e-12 {
            break;
        }
    }
    // Column norms are the singular values.
    let mut order: Vec<usize> = (0..n).collect();
    let mut sv = vec![0.0f32; n];
    for j in 0..n {
        let norm: f64 = (0..m).map(|i| (u[(i, j)] as f64).powi(2)).sum::<f64>().sqrt();
        sv[j] = norm as f32;
    }
    order.sort_by(|&a_, &b_| sv[b_].partial_cmp(&sv[a_]).unwrap());
    let mut uo = Mat::zeros(m, n);
    let mut vto = Mat::zeros(n, n);
    let mut so = vec![0.0f32; n];
    for (k, &j) in order.iter().enumerate() {
        so[k] = sv[j];
        let inv = if sv[j] > 1e-20 { 1.0 / sv[j] } else { 0.0 };
        for i in 0..m {
            uo[(i, k)] = u[(i, j)] * inv;
        }
        for i in 0..n {
            vto[(k, i)] = v[(i, j)];
        }
    }
    Svd { u: uo, s: so, vt: vto }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn reconstruct(s: &Svd) -> Mat {
        let k = s.s.len();
        let mut ds = Mat::zeros(k, k);
        for i in 0..k {
            ds[(i, i)] = s.s[i];
        }
        s.u.matmul(&ds).matmul(&s.vt)
    }

    #[test]
    fn svd_reconstructs_tall() {
        let mut rng = Rng::seed(0);
        let a = Mat::randn(8, 5, &mut rng);
        let d = reconstruct(&svd(&a)).sub(&a).fro_norm() / a.fro_norm();
        assert!(d < 1e-4, "rel err {d}");
    }

    #[test]
    fn svd_reconstructs_wide() {
        let mut rng = Rng::seed(1);
        let a = Mat::randn(4, 9, &mut rng);
        let d = reconstruct(&svd(&a)).sub(&a).fro_norm() / a.fro_norm();
        assert!(d < 1e-4, "rel err {d}");
    }

    #[test]
    fn svd_orthonormal_and_sorted() {
        let mut rng = Rng::seed(2);
        let a = Mat::randn(7, 7, &mut rng);
        let Svd { u, s, vt } = svd(&a);
        let utu = u.t().matmul(&u);
        assert!(utu.sub(&Mat::eye(7)).fro_norm() < 1e-3);
        let vvt = vt.matmul(&vt.t());
        assert!(vvt.sub(&Mat::eye(7)).fro_norm() < 1e-3);
        for w in s.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }

    #[test]
    fn svd_diag_exact() {
        let a = Mat::from_vec(3, 3, vec![3., 0., 0., 0., 5., 0., 0., 0., 1.]);
        let s = svd(&a).s;
        assert!((s[0] - 5.0).abs() < 1e-5);
        assert!((s[1] - 3.0).abs() < 1e-5);
        assert!((s[2] - 1.0).abs() < 1e-5);
    }
}
