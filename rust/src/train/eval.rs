//! Evaluation + generation: batched loss via the eval artifact, and
//! incremental decoding with per-request sampling.
//!
//! [`GenModel`] carries two decode paths that are bit-identical for the
//! same logits:
//!
//! * **KV-cached** ([`crate::runtime::DecodeSession`], native backend):
//!   prefill the prompt once, then one O(t) step per generated token;
//! * **full recompute** (`fwd_M_BxT` artifact, any backend): re-run the
//!   whole fixed-shape forward per token — the reference path, and the
//!   only one AOT artifacts can serve.
//!
//! Both paths share one driver ([`GenModel::generate_stream`]) that owns
//! prompt encoding, per-request sampling ([`DecodeRequest`]: `max_new`,
//! temperature, top-k, stop token, seed) and the per-token callback used
//! for streamed replies, so cached-vs-recompute equality reduces to
//! logits equality (asserted bitwise by the generation proptests).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::data::batch::{encode_prompt, supervised_batch};
use crate::data::tokenizer::{Tokenizer, EOS, PAD};
use crate::data::{Batch, Example};
use crate::runtime::{
    DecodeSession, DecoderProvider, Executable, Executor, PagedDecodeSession, Tensor,
};
use crate::serve::kvpool::KvPoolConfig;
use crate::util::rng::Rng;

/// One generation request: prompt + sampling parameters.
#[derive(Debug, Clone)]
pub struct DecodeRequest {
    pub prompt: String,
    /// Maximum tokens to generate for this request.
    pub max_new: usize,
    /// `<= 0.0` = greedy argmax; otherwise softmax temperature.
    pub temperature: f32,
    /// Restrict sampling to the k highest logits (`0` = whole vocab).
    pub top_k: usize,
    /// Extra stop token (EOS and PAD always stop).
    pub stop: Option<i32>,
    /// Seed for the per-request sampling stream (temperature > 0).
    pub seed: u64,
}

impl DecodeRequest {
    /// Greedy decoding defaults.
    pub fn greedy(prompt: impl Into<String>, max_new: usize) -> Self {
        Self {
            prompt: prompt.into(),
            max_new,
            temperature: 0.0,
            top_k: 0,
            stop: None,
            seed: 0,
        }
    }
}

/// Deterministic per-request token sampler.
///
/// One sampler is created per request from its [`DecodeRequest`]
/// parameters and consumed one [`TokenSampler::sample`] call per decode
/// step, so the token sequence is a pure function of the request
/// (seeded RNG) and the logits sequence — identical whether the logits
/// came from full recompute, a contiguous KV session or the paged
/// continuous-batching path. Public so the serving engine's per-token
/// scheduler draws from exactly the same stream as the batch driver.
pub struct TokenSampler {
    temperature: f32,
    top_k: usize,
    rng: Rng,
}

impl TokenSampler {
    /// Build the sampler for one request (seeds the per-request RNG).
    pub fn new(req: &DecodeRequest) -> Self {
        Self {
            temperature: req.temperature,
            top_k: req.top_k,
            rng: Rng::seed(req.seed ^ 0x5A3F_7E11),
        }
    }

    /// Draw the next token id from one row of next-token logits:
    /// greedy argmax when temperature ≤ 0, otherwise a top-k-filtered
    /// softmax draw at the configured temperature.
    pub fn sample(&mut self, logits: &[f32]) -> i32 {
        if self.temperature <= 0.0 {
            return argmax(logits) as i32;
        }
        // top-k filter (0 = everything), softmax at temperature, CDF draw
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        if self.top_k > 0 && self.top_k < logits.len() {
            idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
            idx.truncate(self.top_k);
        }
        let maxv = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
        let weights: Vec<f64> =
            idx.iter().map(|&i| (((logits[i] - maxv) / self.temperature) as f64).exp()).collect();
        let total: f64 = weights.iter().sum();
        let mut u = self.rng.f64() * total;
        for (k, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return idx[k] as i32;
            }
        }
        idx[idx.len() - 1] as i32
    }
}

/// A merged (base-layout) model ready for forward passes and decoding.
pub struct GenModel {
    pub model: String,
    pub b: usize,
    pub t: usize,
    fwd: Arc<dyn Executable>,
    eval: Arc<dyn Executable>,
    pub params: HashMap<String, Tensor>,
    vocab: usize,
    decoder: Option<Arc<dyn DecoderProvider>>,
}

impl GenModel {
    pub fn new(rt: &dyn Executor, model: &str, params: HashMap<String, Tensor>) -> Result<Self> {
        let mm = rt.artifacts().model(model)?;
        let (b, t) = mm.default_batch();
        let fwd = rt
            .load(&format!("fwd_{model}_{b}x{t}"))
            .context("forward artifact")?;
        let eval = rt
            .load(&format!("eval_{model}_{b}x{t}"))
            .context("eval artifact")?;
        Ok(Self {
            model: model.to_string(),
            b,
            t,
            fwd,
            eval,
            params,
            vocab: mm.dims.vocab,
            decoder: rt.decoder(),
        })
    }

    /// Whether generation runs the KV-cached incremental path.
    pub fn has_decoder(&self) -> bool {
        self.decoder.is_some()
    }

    /// Vocabulary size of the underlying model (logits row width).
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Open a continuous-batching decode session with `rows` slots over
    /// a paged KV pool sized by `cfg`, if the backend supports one.
    /// `Ok(None)` means "no paged path here" — callers fall back to the
    /// wave-scheduled [`GenModel::generate_stream`] driver.
    pub fn open_paged_session(
        &self,
        rows: usize,
        cfg: KvPoolConfig,
    ) -> Result<Option<Box<dyn PagedDecodeSession + '_>>> {
        match &self.decoder {
            Some(p) => p.open_paged(&self.model, &self.params, rows, self.t, cfg),
            None => Ok(None),
        }
    }

    /// Masked LM loss + token accuracy on one batch.
    pub fn eval_batch(&self, batch: &Batch) -> Result<(f32, f32)> {
        let mut pool = self.params.clone();
        pool.insert("tokens".into(), batch.tokens.clone());
        pool.insert("targets".into(), batch.targets.clone());
        pool.insert("loss_mask".into(), batch.loss_mask.clone());
        let out = self.eval.run_named(&pool)?;
        let loss = out["loss"].scalar_value_f32()?;
        let denom = batch.answer_tokens().max(1) as f32;
        let acc = out["ncorrect"].scalar_value_f32()? / denom;
        Ok((loss, acc))
    }

    /// Greedy-decode up to `max_new` tokens per prompt (KV-cached when
    /// the backend provides a decoder, full recompute otherwise).
    pub fn generate(&self, prompts: &[String], max_new: usize) -> Result<Vec<String>> {
        let reqs: Vec<DecodeRequest> =
            prompts.iter().map(|p| DecodeRequest::greedy(p.clone(), max_new)).collect();
        self.generate_stream(&reqs, |_, _| {})
    }

    /// Decode every request, invoking `on_token(request_index, token)` as
    /// each token is produced (the engine's streaming hook). Returns the
    /// decoded text per request.
    pub fn generate_stream(
        &self,
        reqs: &[DecodeRequest],
        mut on_token: impl FnMut(usize, i32),
    ) -> Result<Vec<String>> {
        self.run_decode(reqs, self.decoder.is_some(), &mut on_token)
    }

    /// Reference path: full fixed-shape recompute per token, never the KV
    /// cache. Public so tests can assert cached/uncached bit-identity.
    pub fn generate_full_recompute(
        &self,
        reqs: &[DecodeRequest],
        mut on_token: impl FnMut(usize, i32),
    ) -> Result<Vec<String>> {
        self.run_decode(reqs, false, &mut on_token)
    }

    /// Full-sequence logits for the current `rows` buffer.
    fn full_logits(&self, rows: &[Vec<i32>]) -> Result<Vec<f32>> {
        let flat: Vec<i32> = rows.iter().flatten().copied().collect();
        let mut pool = self.params.clone();
        pool.insert("tokens".into(), Tensor::i32(vec![self.b, self.t], flat));
        let out = self.fwd.run_named(&pool)?;
        Ok(out["logits"].as_f32()?.to_vec())
    }

    fn run_decode(
        &self,
        reqs: &[DecodeRequest],
        use_cache: bool,
        on_token: &mut dyn FnMut(usize, i32),
    ) -> Result<Vec<String>> {
        let tk = Tokenizer;
        let vocab = self.vocab;
        let mut results = Vec::with_capacity(reqs.len());
        let pad_req = DecodeRequest::greedy("", 0);
        for (chunk_idx, chunk) in reqs.chunks(self.b).enumerate() {
            let mut rows: Vec<Vec<i32>> = Vec::with_capacity(self.b);
            let mut pos: Vec<usize> = Vec::with_capacity(self.b);
            let mut done: Vec<bool> = Vec::with_capacity(self.b);
            let mut samplers: Vec<TokenSampler> = Vec::with_capacity(self.b);
            for i in 0..self.b {
                let req = chunk.get(i);
                let (toks, gp) = encode_prompt(&tk, req.map_or("", |r| r.prompt.as_str()), self.t);
                rows.push(toks);
                pos.push(gp.min(self.t - 1));
                done.push(req.is_none());
                samplers.push(TokenSampler::new(req.unwrap_or(&pad_req)));
            }
            let mut generated: Vec<Vec<i32>> = vec![Vec::new(); self.b];
            let max_new_cap = chunk.iter().map(|r| r.max_new).max().unwrap_or(0);

            let mut session: Option<Box<dyn DecodeSession + '_>> = if use_cache {
                match &self.decoder {
                    Some(p) => Some(p.open_session(&self.model, &self.params, self.b, self.t)?),
                    None => None,
                }
            } else {
                None
            };

            // Next-token logits per row (readout position = pos - 1).
            let mut cur = vec![0.0f32; self.b * vocab];
            if let Some(sess) = session.as_deref_mut() {
                // prefill: feed prompt tokens; capture logits where the
                // fed token is the last prompt token
                let maxp = (0..self.b).filter(|&r| !done[r]).map(|r| pos[r]).max().unwrap_or(0);
                for step_i in 0..maxp {
                    let toks: Vec<Option<i32>> = (0..self.b)
                        .map(|r| {
                            if !done[r] && step_i < pos[r] {
                                Some(rows[r][step_i])
                            } else {
                                None
                            }
                        })
                        .collect();
                    let lg = sess.step(&toks)?;
                    for r in 0..self.b {
                        if !done[r] && step_i + 1 == pos[r] {
                            cur[r * vocab..(r + 1) * vocab]
                                .copy_from_slice(&lg[r * vocab..(r + 1) * vocab]);
                        }
                    }
                }
            } else {
                let lg = self.full_logits(&rows)?;
                for r in 0..self.b {
                    if !done[r] {
                        let off = (r * self.t + pos[r] - 1) * vocab;
                        cur[r * vocab..(r + 1) * vocab].copy_from_slice(&lg[off..off + vocab]);
                    }
                }
            }

            for _ in 0..max_new_cap {
                if done.iter().all(|&d| d) {
                    break;
                }
                // sample one token per live row
                let mut next: Vec<Option<i32>> = vec![None; self.b];
                for r in 0..self.b {
                    if done[r] || pos[r] >= self.t || generated[r].len() >= chunk[r].max_new {
                        done[r] = true;
                        continue;
                    }
                    let tok = samplers[r].sample(&cur[r * vocab..(r + 1) * vocab]);
                    if tok == EOS || tok == PAD || chunk[r].stop == Some(tok) {
                        done[r] = true;
                        continue;
                    }
                    rows[r][pos[r]] = tok;
                    pos[r] += 1;
                    generated[r].push(tok);
                    on_token(chunk_idx * self.b + r, tok);
                    next[r] = Some(tok);
                }
                if next.iter().all(|t| t.is_none()) {
                    continue;
                }
                // advance logits past the freshly appended tokens
                if let Some(sess) = session.as_deref_mut() {
                    let lg = sess.step(&next)?;
                    for r in 0..self.b {
                        if next[r].is_some() {
                            cur[r * vocab..(r + 1) * vocab]
                                .copy_from_slice(&lg[r * vocab..(r + 1) * vocab]);
                        }
                    }
                } else {
                    let lg = self.full_logits(&rows)?;
                    for r in 0..self.b {
                        if next[r].is_some() {
                            let off = (r * self.t + pos[r] - 1) * vocab;
                            cur[r * vocab..(r + 1) * vocab]
                                .copy_from_slice(&lg[off..off + vocab]);
                        }
                    }
                }
            }
            for g in generated.iter().take(chunk.len()) {
                results.push(tk.decode_until_eos(g));
            }
        }
        Ok(results)
    }
}

fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

/// Exact-match accuracy of greedy generations against the gold answers.
pub fn task_accuracy(model: &GenModel, examples: &[Example]) -> Result<f64> {
    let prompts: Vec<String> = examples.iter().map(|e| e.prompt.clone()).collect();
    let max_new = examples.iter().map(|e| e.answer.len() + 1).max().unwrap_or(8);
    let outs = model.generate(&prompts, max_new)?;
    let correct = outs
        .iter()
        .zip(examples)
        .filter(|(got, ex)| got.trim() == ex.answer)
        .count();
    Ok(correct as f64 / examples.len().max(1) as f64)
}

/// Mean supervised loss of a model over examples (memorization metric).
pub fn eval_loss(model: &GenModel, examples: &[Example]) -> Result<f32> {
    let tk = Tokenizer;
    let mut total = 0.0f64;
    let mut batches = 0usize;
    for chunk in examples.chunks(model.b) {
        let batch = supervised_batch(&tk, chunk, model.b, model.t);
        let (loss, _) = model.eval_batch(&batch)?;
        total += loss as f64;
        batches += 1;
    }
    Ok((total / batches.max(1) as f64) as f32)
}
