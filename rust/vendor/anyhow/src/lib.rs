//! Minimal `anyhow`-compatible error crate, vendored so the workspace
//! builds hermetically (no network, no registry).
//!
//! Implements the subset this repository uses: [`Error`] with a context
//! chain, the [`Result`] alias, the [`Context`] extension trait for both
//! `Result` and `Option`, and the `anyhow!` / `bail!` macros. `{e}` prints
//! the outermost message; `{e:#}` prints the whole chain joined by `: `,
//! matching upstream `anyhow` formatting.

use std::fmt;

/// Error with an ordered context chain (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message.
    pub fn msg(message: impl fmt::Display) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, context: impl fmt::Display) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

// Like upstream anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion
// coherent.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` alias with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| {
            let e: Error = e.into();
            e.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| {
            let e: Error = e.into();
            e.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = io_err().into();
        let e = e.context("reading file").context("loading config");
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: reading file: gone");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn result_and_option_context() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("ctx").unwrap_err();
        assert_eq!(format!("{e:#}"), "ctx: gone");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
        // context also nests on an already-anyhow Result
        let r2: Result<()> = Err(anyhow!("inner {}", 1));
        let e2 = r2.context("outer").unwrap_err();
        assert_eq!(format!("{e2:#}"), "outer: inner 1");
    }

    #[test]
    fn macros() {
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        let x = 3;
        let e = anyhow!("value {x} and {}", 4);
        assert_eq!(format!("{e}"), "value 3 and 4");
        fn f() -> Result<()> {
            bail!("boom {}", 9)
        }
        assert_eq!(format!("{}", f().unwrap_err()), "boom 9");
    }
}
