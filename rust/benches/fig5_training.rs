//! Figure 5 (bench form): end-to-end train-step latency per method on the
//! `small` model through whichever backend is available (native interprets
//! fullft + s2ft; the pjrt feature adds the full AOT method set). The
//! `repro experiment fig5` harness covers the `base`-model sweep with
//! memory accounting; this bench gives tight per-step latency
//! distributions for regressions.

use repro::data::{lm_batch, pretrain_corpus, Tokenizer};
use repro::runtime::{open_backend, Executable, Executor, Tensor};
use repro::train::Trainer;
use repro::util::bench::BenchSuite;
use repro::util::rng::Rng;

fn main() {
    let rt = match open_backend("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            // leave a machine-readable record so CI can tell a skipped
            // bench apart from a lost artifact
            BenchSuite::save_skipped("fig5_training", &format!("{e:#}"));
            return;
        }
    };
    let model = "small";
    let mm = rt.artifacts().model(model).expect("small model meta").clone();
    let (b, t) = mm.default_batch();
    let init = rt.load(&format!("init_{model}")).expect("init artifact");
    let outs = init.run(&[Tensor::scalar_i32(1)]).expect("init run");
    let base: std::collections::HashMap<String, Tensor> = init
        .spec()
        .outputs
        .iter()
        .map(|s| s.name.clone())
        .zip(outs)
        .collect();

    let tk = Tokenizer;
    let corpus = pretrain_corpus(3, 200_000);
    let mut suite = BenchSuite::new("fig5_training").slow();
    println!(
        "Fig 5 (bench): one optimizer step, model=small {b}x{t}, backend {}\n",
        rt.platform()
    );
    for method in ["fullft", "lora", "dora", "spft", "lisa", "galore", "s2ft", "s2ft-pallas"] {
        if mm.methods.get(method).is_none() {
            continue;
        }
        let mut rng = Rng::seed(5);
        let calib = lm_batch(&tk, &corpus, &mut rng, b, t);
        let mut trainer = match Trainer::new(rt.as_ref(), model, method, &base, 3, &calib) {
            Ok(tr) => tr,
            Err(e) => {
                eprintln!("  {method}: {e:#}");
                continue;
            }
        };
        // compile + warm
        let batch = lm_batch(&tk, &corpus, &mut rng, b, t);
        trainer.train_step(&batch).expect("warmup step");
        suite.bench(&format!("train_step/{method}"), || {
            let batch = lm_batch(&tk, &corpus, &mut rng, b, t);
            trainer.train_step(&batch).expect("train step");
        });
        rt.evict(&format!("train_{model}_{method}_{b}x{t}"));
    }
    println!("\nPaper shape: s2ft < lora/dora < fullft in step latency.");
    suite.save();
}
