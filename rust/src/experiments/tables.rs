//! Tables 1–3: commonsense reasoning, arithmetic reasoning, instruction
//! following. Same harness, different suite + method list.

use anyhow::Result;

use crate::data::{finetune_examples, ARITHMETIC, COMMONSENSE, INSTRUCT};
use crate::runtime::{open_backend, Executor};
use crate::train::GenModel;

use super::common::{
    evaluate_suite, finetune, pretrained_cached, print_table, save_result, table_json,
};

const MODEL: &str = "small";

struct TableSpec {
    id: &'static str,
    title: &'static str,
    suite: &'static str,
    tasks: &'static [crate::data::Task],
    methods: &'static [(&'static str, &'static str)],
}

fn run_table(artifacts: &str, quick: bool, spec: &TableSpec) -> Result<()> {
    let rt = open_backend(artifacts)?;
    let (pre_steps, ft_steps, n_eval) = if quick { (60, 30, 8) } else { (800, 250, 32) };
    let base = pretrained_cached(&rt, MODEL, pre_steps, 42)?;
    let examples = finetune_examples(spec.suite, 2000, 13);

    let subtasks: Vec<String> = spec.tasks.iter().map(|t| t.name.to_string()).collect();
    let mut rows = Vec::new();
    // Optional method filter (comma list of tags) + incremental result
    // merging: long runs can be chunked across invocations, each chunk
    // updating results/<id>.json (REPRO_METHODS=s2ft,lisa repro experiment tab1).
    let filter: Option<Vec<String>> = std::env::var("REPRO_METHODS")
        .ok()
        .map(|s| s.split(',').map(|x| x.trim().to_string()).collect());
    let keep = |tag: &str| filter.as_ref().map_or(true, |f| f.iter().any(|x| x == tag));

    if keep("vanilla") {
        // Vanilla row: the pre-trained model, no fine-tuning.
        let vanilla = GenModel::new(&rt, MODEL, base.clone())?;
        let (accs, avg) = evaluate_suite(&vanilla, spec.tasks, n_eval, 0xEAA)?;
        rows.push(("Vanilla".to_string(), accs.into_iter().map(|(_, a)| a).collect(), avg));
    }

    for (label, tag) in spec.methods {
        if !keep(tag) {
            continue;
        }
        if rt.artifacts().model(MODEL)?.methods.get(*tag).is_none() {
            println!("  (skipping {label}: {tag} not built)");
            continue;
        }
        println!("{}: fine-tuning {label} ({tag}) for {ft_steps} steps...", spec.id);
        let trainer = finetune(&rt, MODEL, tag, &base, &examples, ft_steps, 17)?;
        let merged = trainer.merged_params(&rt)?;
        let model = GenModel::new(&rt, MODEL, merged)?;
        let (accs, avg) = evaluate_suite(&model, spec.tasks, n_eval, 0xEAA)?;
        println!("  -> avg {avg:.1}% (train loss {:.3})", trainer.metrics.tail_loss(10));
        rows.push((label.to_string(), accs.into_iter().map(|(_, a)| a).collect(), avg));
    }
    // Merge with rows from previous chunked invocations (method name keyed;
    // fresh rows win; ordering = vanilla + spec order).
    let mut merged: Vec<(String, Vec<f64>, f64)> = Vec::new();
    if let Ok(prev) = std::fs::read_to_string(format!("results/{}.json", spec.id)) {
        if let Ok(js) = crate::util::json::Json::parse(&prev) {
            if let Some(prows) = js.opt("rows").and_then(|r| r.as_arr().ok()) {
                for pr in prows {
                    let m = pr.get("method").and_then(|v| v.as_str().map(String::from));
                    let avg = pr.get("avg").and_then(|v| v.as_f64());
                    let accs: Option<Vec<f64>> = pr.get("accs").and_then(|v| {
                        v.as_arr().map(|a| a.iter().filter_map(|x| x.as_f64().ok()).collect())
                    }).ok();
                    if let (Ok(m), Ok(avg), Some(accs)) = (m, avg, accs) {
                        if !rows.iter().any(|(name, _, _)| *name == m) {
                            merged.push((m, accs, avg));
                        }
                    }
                }
            }
        }
    }
    merged.extend(rows);
    // stable order: Vanilla first, then spec.methods order
    let order: Vec<&str> = std::iter::once("Vanilla")
        .chain(spec.methods.iter().map(|(l, _)| *l))
        .collect();
    merged.sort_by_key(|(name, _, _)| {
        order.iter().position(|o| o == name).unwrap_or(usize::MAX)
    });
    print_table(spec.title, &subtasks, &merged);
    save_result(spec.id, &table_json(&subtasks, &merged));
    Ok(())
}

/// Table 1: eight commonsense reasoning subtasks.
pub fn run_tab1(artifacts: &str, quick: bool) -> Result<()> {
    run_table(
        artifacts,
        quick,
        &TableSpec {
            id: "tab1",
            title: "Table 1: commonsense reasoning (test accuracy %)",
            suite: "commonsense",
            tasks: &COMMONSENSE,
            methods: &[
                ("Full FT", "fullft"),
                ("LoRA", "lora"),
                ("DoRA", "dora"),
                ("GaLore", "galore"),
                ("SpFT", "spft"),
                ("LISA", "lisa"),
                ("S2FT (ours)", "s2ft"),
            ],
        },
    )
}

/// Table 2: seven arithmetic reasoning subtasks (FT on the Math10K-analogue
/// mixture; MultiArith/AddSub/SingleEq/SVAMP are near-OOD).
pub fn run_tab2(artifacts: &str, quick: bool) -> Result<()> {
    run_table(
        artifacts,
        quick,
        &TableSpec {
            id: "tab2",
            title: "Table 2: arithmetic reasoning (test accuracy %)",
            suite: "arithmetic",
            tasks: &ARITHMETIC,
            methods: &[
                ("Full FT", "fullft"),
                ("LoRA", "lora"),
                ("DoRA", "dora"),
                ("S2FT (ours)", "s2ft"),
            ],
        },
    )
}

/// Table 3: instruction following across eight MT-Bench-like categories
/// (exact-match score stands in for the GPT-4 judge).
pub fn run_tab3(artifacts: &str, quick: bool) -> Result<()> {
    run_table(
        artifacts,
        quick,
        &TableSpec {
            id: "tab3",
            title: "Table 3: instruction following (category score %)",
            suite: "instruct",
            tasks: &INSTRUCT,
            methods: &[
                ("Full FT", "fullft"),
                ("LoRA", "lora"),
                ("GaLore", "galore"),
                ("LISA", "lisa"),
                ("S2FT (ours)", "s2ft"),
            ],
        },
    )
}
