//! KV-cached incremental decode for the native interpreter.
//!
//! Two session flavors over the same arithmetic:
//!
//! * [`NativeDecodeSession`] — fixed rows with private contiguous
//!   `(b, t_max, d)` K/V buffers (the original wave-scheduling path,
//!   still what [`crate::train::GenModel::generate_stream`] drives);
//! * [`NativePagedDecodeSession`] — continuous-batching slots whose K/V
//!   lives in a shared block-paged [`KvPool`]
//!   ([`crate::serve::kvpool`]): streams admit/retire mid-flight, draw
//!   blocks lazily and attend through
//!   [`crate::kernels::attn_decode_paged`].
//!
//! Each step embeds the new tokens, runs the per-layer projections at
//! batch size = #active rows, appends rotated K / V to the cache and
//! attends through the single-query decode kernel — O(t) work per
//! generated token versus the O(t²) full-sequence recompute of the
//! `fwd` artifact.
//!
//! Bit-identity contract: every arithmetic step (embedding copy, RMSNorm,
//! GEMM reduction order, RoPE rotation, softmax max/exp/normalize order,
//! weighted-value accumulation, residual adds, SwiGLU) reproduces the
//! exact operation order of the full forward in `native/model.rs` for the
//! same prefix, so greedy decode through a session matches full recompute
//! bit-for-bit (asserted by the generation proptests). The paged session
//! adds only block-table address translation on the K/V reads — never
//! arithmetic — so paged and contiguous sessions are bit-identical for
//! the same per-row token schedule regardless of which other streams
//! come and go (asserted by `paged_session_matches_contiguous` below and
//! the serve proptests). Only causal attention mixes positions, and it
//! only looks backward — a prefix's activations never depend on what
//! comes after it.
//!
//! The paged session can additionally carry an *unfused* S²FT adapter
//! ([`PagedDecodeSession::set_unfused_adapter`]): the per-layer delta
//! rows are applied at decode time as a gather + dense GEMV on top of
//! the base `wo` / `wd` projections — the same arithmetic as
//! [`crate::adapter::parallel::s2ft_parallel`] — instead of being
//! scatter-added into the weights. Fused and unfused application of the
//! same adapter agree numerically but not bit-for-bit (the delta
//! contribution is reduced separately rather than inside the base GEMM),
//! so the bit-identity contract above is stated per application path.

// s2ft-analyze: allow(nondet) reason="weight maps are keyed lookup only — never iterated — so HashMap order cannot reach the decoded tokens"
use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::adapter::{AnyAdapter, S2ftLayerDelta};
use crate::kernels::{attn_decode, attn_decode_paged, gemm, gemm_nt, gemv_acc};
use crate::runtime::meta::{Meta, ModelMeta};
use crate::runtime::{DecodeSession, DecoderProvider, PagedDecodeSession, Tensor};
use crate::serve::kvpool::{KvPool, KvPoolConfig, PoolExhausted, PoolUsage};

use super::model::{rms_norm_fwd, rope_tables, sigmoid};

/// [`DecoderProvider`] for [`super::NativeBackend`]: holds only the meta
/// handle, so opening a session is allocation of the caches plus borrows
/// of the caller's weight slices (no weight copies).
pub struct NativeDecoderProvider {
    pub(super) meta: Arc<Meta>,
}

impl NativeDecoderProvider {
    fn model(&self, model: &str) -> Result<ModelMeta> {
        self.meta
            .models
            .get(model)
            .cloned()
            .ok_or_else(|| anyhow!("model {model:?} not in meta"))
    }
}

impl DecoderProvider for NativeDecoderProvider {
    fn open_session<'p>(
        &self,
        model: &str,
        params: &'p HashMap<String, Tensor>,
        b: usize,
        t_max: usize,
    ) -> Result<Box<dyn DecodeSession + 'p>> {
        let mm = self.model(model)?;
        Ok(Box::new(NativeDecodeSession::new(mm, params, b, t_max)?))
    }

    fn open_paged<'p>(
        &self,
        model: &str,
        params: &'p HashMap<String, Tensor>,
        rows: usize,
        t_max: usize,
        cfg: KvPoolConfig,
    ) -> Result<Option<Box<dyn PagedDecodeSession + 'p>>> {
        let mm = self.model(model)?;
        Ok(Some(Box::new(NativePagedDecodeSession::new(mm, params, rows, t_max, cfg)?)))
    }
}

/// Validate and borrow every base-layout weight slice a decode needs.
fn borrow_weights<'p>(
    mm: &ModelMeta,
    params: &'p HashMap<String, Tensor>,
) -> Result<HashMap<String, &'p [f32]>> {
    let mut w = HashMap::new();
    for s in &mm.base_params {
        let t = params
            .get(&s.name)
            .ok_or_else(|| anyhow!("decode: missing weight {:?}", s.name))?;
        if t.shape != s.shape {
            bail!(
                "decode: weight {:?} shape {:?} != expected {:?}",
                s.name,
                t.shape,
                s.shape
            );
        }
        w.insert(s.name.clone(), t.as_f32()?);
    }
    Ok(w)
}

/// In-place RoPE on one `(heads·hd)` row at absolute position `pos` —
/// same pair rotation as the full forward's `apply_rope`.
fn rope_row(cos: &[f32], sin: &[f32], x: &mut [f32], heads: usize, hd: usize, pos: usize) {
    let half = hd / 2;
    for hh in 0..heads {
        let off = hh * hd;
        for j in 0..half {
            let c = cos[pos * half + j];
            let s = sin[pos * half + j];
            let x1 = x[off + 2 * j];
            let x2 = x[off + 2 * j + 1];
            x[off + 2 * j] = x1 * c - x2 * s;
            x[off + 2 * j + 1] = x1 * s + x2 * c;
        }
    }
}

/// One live decode: borrowed base-layout weights + owned KV caches.
///
/// Cache memory is `2 · n_layers · b · t_max · d_model · 4` bytes
/// (K and V, f32) — e.g. the builtin `small` model at b=8, t_max=64
/// caches 4·8·64·256·2·4 B = 4.2 MB.
pub struct NativeDecodeSession<'p> {
    mm: ModelMeta,
    w: HashMap<String, &'p [f32]>,
    b: usize,
    t_max: usize,
    pos: Vec<usize>,
    /// per layer: (b, t_max, d) rotated keys
    k_cache: Vec<Vec<f32>>,
    /// per layer: (b, t_max, d) values
    v_cache: Vec<Vec<f32>>,
    cos: Vec<f32>,
    sin: Vec<f32>,
}

impl<'p> NativeDecodeSession<'p> {
    fn new(
        mm: ModelMeta,
        params: &'p HashMap<String, Tensor>,
        b: usize,
        t_max: usize,
    ) -> Result<Self> {
        let w = borrow_weights(&mm, params)?;
        let d = mm.dims.d_model;
        let hd = mm.head_dim();
        let n_layers = mm.dims.n_layers;
        let (cos, sin) = rope_tables(t_max, hd, mm.dims.rope_theta);
        Ok(Self {
            w,
            b,
            t_max,
            pos: vec![0; b],
            k_cache: (0..n_layers).map(|_| vec![0.0; b * t_max * d]).collect(),
            v_cache: (0..n_layers).map(|_| vec![0.0; b * t_max * d]).collect(),
            cos,
            sin,
            mm,
        })
    }

    fn weight(&self, name: &str) -> &'p [f32] {
        self.w[name]
    }
}

impl DecodeSession for NativeDecodeSession<'_> {
    fn batch(&self) -> usize {
        self.b
    }

    fn max_seq(&self) -> usize {
        self.t_max
    }

    fn pos(&self, row: usize) -> usize {
        self.pos[row]
    }

    fn step(&mut self, tokens: &[Option<i32>]) -> Result<Vec<f32>> {
        let d = self.mm.dims.d_model;
        let heads = self.mm.dims.n_heads;
        let hd = d / heads;
        let ff = self.mm.dims.d_ff;
        let vocab = self.mm.dims.vocab;
        let eps = self.mm.dims.norm_eps as f32;
        let scale = 1.0 / (hd as f32).sqrt();
        if tokens.len() != self.b {
            bail!("decode: {} token slots != batch {}", tokens.len(), self.b);
        }

        // active rows, their cache rows and (post-append) positions
        let mut rows = Vec::new();
        let mut toks = Vec::new();
        for (r, t) in tokens.iter().enumerate() {
            if let Some(t) = *t {
                if self.pos[r] >= self.t_max {
                    bail!("decode: row {r} exceeded t_max {}", self.t_max);
                }
                rows.push(r);
                toks.push(t);
            }
        }
        let mut out = vec![0.0f32; self.b * vocab];
        let m = rows.len();
        if m == 0 {
            return Ok(out);
        }
        let qpos: Vec<usize> = rows.iter().map(|&r| self.pos[r]).collect();

        let embed = self.weight("embed");
        let mut h = vec![0.0f32; m * d];
        for (j, &tok) in toks.iter().enumerate() {
            let tok = tok as usize;
            if tok >= vocab {
                bail!("decode: token id {tok} out of vocab {vocab}");
            }
            h[j * d..(j + 1) * d].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
        }

        for i in 0..self.mm.dims.n_layers {
            let (x1, _) = rms_norm_fwd(&h, self.weight(&format!("L{i}.norm1")), m, d, eps);
            let mut q = gemm(&x1, self.weight(&format!("L{i}.wq")), m, d, d);
            let mut k = gemm(&x1, self.weight(&format!("L{i}.wk")), m, d, d);
            let v = gemm(&x1, self.weight(&format!("L{i}.wv")), m, d, d);
            for (j, (&r, &p)) in rows.iter().zip(&qpos).enumerate() {
                rope_row(&self.cos, &self.sin, &mut q[j * d..(j + 1) * d], heads, hd, p);
                rope_row(&self.cos, &self.sin, &mut k[j * d..(j + 1) * d], heads, hd, p);
                let off = (r * self.t_max + p) * d;
                self.k_cache[i][off..off + d].copy_from_slice(&k[j * d..(j + 1) * d]);
                self.v_cache[i][off..off + d].copy_from_slice(&v[j * d..(j + 1) * d]);
            }
            let attn = attn_decode(
                &q,
                &self.k_cache[i],
                &self.v_cache[i],
                &rows,
                &qpos,
                heads,
                hd,
                self.t_max,
                scale,
            );
            // h_mid = h + attn @ wo (residual add, same order as forward)
            let wo_out = gemm(&attn, self.weight(&format!("L{i}.wo")), m, d, d);
            for (hv, ov) in h.iter_mut().zip(&wo_out) {
                *hv += ov;
            }
            let (x2, _) = rms_norm_fwd(&h, self.weight(&format!("L{i}.norm2")), m, d, eps);
            let u = gemm(&x2, self.weight(&format!("L{i}.wu")), m, d, ff);
            let g = gemm(&x2, self.weight(&format!("L{i}.wg")), m, d, ff);
            let mut act = vec![0.0f32; m * ff];
            for j in 0..m * ff {
                act[j] = u[j] * g[j] * sigmoid(g[j]);
            }
            let wd_out = gemm(&act, self.weight(&format!("L{i}.wd")), m, ff, d);
            for (hv, ov) in h.iter_mut().zip(&wd_out) {
                *hv += ov;
            }
        }

        let (xf, _) = rms_norm_fwd(&h, self.weight("norm_f"), m, d, eps);
        let logits = gemm_nt(&xf, embed, m, d, vocab);
        for (j, &r) in rows.iter().enumerate() {
            out[r * vocab..(r + 1) * vocab].copy_from_slice(&logits[j * vocab..(j + 1) * vocab]);
            self.pos[r] += 1;
        }
        Ok(out)
    }
}

/// Per-stream paged-cache state: the ordered physical block table plus
/// the next logical position.
struct StreamKv {
    table: Vec<u32>,
    pos: usize,
}

/// Continuous-batching decode session: row *slots* over a shared
/// [`KvPool`]. Same arithmetic as [`NativeDecodeSession`]; K/V reads go
/// through each stream's block table instead of a contiguous row.
pub struct NativePagedDecodeSession<'p> {
    mm: ModelMeta,
    w: HashMap<String, &'p [f32]>,
    rows: usize,
    t_max: usize,
    streams: Vec<Option<StreamKv>>,
    pool: KvPool,
    cos: Vec<f32>,
    sin: Vec<f32>,
    /// S²FT adapter applied per step as gather + GEMV instead of being
    /// fused into `w` (validated by `set_unfused_adapter`).
    unfused: Option<Arc<AnyAdapter>>,
}

impl<'p> NativePagedDecodeSession<'p> {
    fn new(
        mm: ModelMeta,
        params: &'p HashMap<String, Tensor>,
        rows: usize,
        t_max: usize,
        cfg: KvPoolConfig,
    ) -> Result<Self> {
        if cfg.block_tokens == 0 {
            bail!("paged decode: block_tokens must be > 0");
        }
        let blocks = if cfg.blocks == 0 {
            // auto-size: every slot can reach t_max, eviction-free
            rows * t_max.div_ceil(cfg.block_tokens)
        } else {
            cfg.blocks
        };
        if blocks == 0 {
            bail!("paged decode: pool needs at least one block");
        }
        let w = borrow_weights(&mm, params)?;
        let d = mm.dims.d_model;
        let hd = mm.head_dim();
        let (cos, sin) = rope_tables(t_max, hd, mm.dims.rope_theta);
        let pool = KvPool::new(mm.dims.n_layers, d, cfg.block_tokens, blocks);
        Ok(Self {
            w,
            rows,
            t_max,
            streams: (0..rows).map(|_| None).collect(),
            pool,
            cos,
            sin,
            unfused: None,
            mm,
        })
    }

    fn weight(&self, name: &str) -> &'p [f32] {
        self.w[name]
    }

    /// Layer `i` of the unfused adapter, if one is set.
    fn unfused_layer(&self, i: usize) -> Option<&S2ftLayerDelta> {
        match self.unfused.as_deref() {
            Some(AnyAdapter::S2ft(a)) => a.layers.get(i),
            _ => None,
        }
    }
}

/// Unfused S²FT delta on one projection: for every batch row `j`,
/// gather the selected input activations of `x` and accumulate the
/// dense delta-rows product into that row of `y` — the decode-time
/// twin of [`crate::adapter::parallel::s2ft_parallel`], with one
/// adapter shared by every row of the batch.
fn apply_unfused_rows(
    x: &[f32],
    rows_idx: &[usize],
    delta: &[f32],
    m: usize,
    k: usize,
    d: usize,
    y: &mut [f32],
) {
    if rows_idx.is_empty() {
        return;
    }
    let mut xs = vec![0.0f32; rows_idx.len()];
    for j in 0..m {
        let xj = &x[j * k..(j + 1) * k];
        for (dst, &r) in xs.iter_mut().zip(rows_idx) {
            *dst = xj[r];
        }
        gemv_acc(&xs, delta, d, 1.0, &mut y[j * d..(j + 1) * d]);
    }
}

impl PagedDecodeSession for NativePagedDecodeSession<'_> {
    fn rows(&self) -> usize {
        self.rows
    }

    fn max_seq(&self) -> usize {
        self.t_max
    }

    fn pos(&self, row: usize) -> usize {
        self.streams[row].as_ref().map_or(0, |s| s.pos)
    }

    fn is_active(&self, row: usize) -> bool {
        self.streams[row].is_some()
    }

    fn admit(&mut self, row: usize) -> Result<()> {
        if row >= self.rows {
            bail!("paged decode: admit to row {row} >= capacity {}", self.rows);
        }
        if self.streams[row].is_some() {
            bail!("paged decode: row {row} already admitted");
        }
        self.streams[row] = Some(StreamKv { table: Vec::new(), pos: 0 });
        Ok(())
    }

    fn retire(&mut self, row: usize) {
        if let Some(st) = self.streams[row].take() {
            self.pool.release(&st.table);
        }
    }

    fn reserve(&mut self, rows: &[usize]) -> std::result::Result<(), PoolExhausted> {
        let bt = self.pool.block_tokens();
        for &r in rows {
            let Some(st) = self.streams.get_mut(r).and_then(|s| s.as_mut()) else {
                continue; // not admitted — step() will report it
            };
            let needed = st.pos / bt + 1;
            while st.table.len() < needed {
                st.table.push(self.pool.alloc()?);
            }
        }
        Ok(())
    }

    fn step(&mut self, tokens: &[Option<i32>]) -> Result<Vec<f32>> {
        let d = self.mm.dims.d_model;
        let heads = self.mm.dims.n_heads;
        let hd = d / heads;
        let ff = self.mm.dims.d_ff;
        let vocab = self.mm.dims.vocab;
        let eps = self.mm.dims.norm_eps as f32;
        let scale = 1.0 / (hd as f32).sqrt();
        let bt = self.pool.block_tokens();
        if tokens.len() != self.rows {
            bail!("paged decode: {} token slots != rows {}", tokens.len(), self.rows);
        }

        // active stepped rows and their (pre-append) positions
        let mut rows = Vec::new();
        let mut toks = Vec::new();
        for (r, t) in tokens.iter().enumerate() {
            if let Some(t) = *t {
                let Some(st) = self.streams[r].as_ref() else {
                    bail!("paged decode: row {r} stepped but not admitted");
                };
                if st.pos >= self.t_max {
                    bail!("paged decode: row {r} exceeded t_max {}", self.t_max);
                }
                if st.table.len() * bt <= st.pos {
                    bail!("paged decode: row {r} stepped without reserve()");
                }
                rows.push(r);
                toks.push(t);
            }
        }
        let mut out = vec![0.0f32; self.rows * vocab];
        let m = rows.len();
        if m == 0 {
            return Ok(out);
        }
        let qpos: Vec<usize> =
            rows.iter().map(|&r| self.streams[r].as_ref().unwrap().pos).collect();

        let embed = self.weight("embed");
        let mut h = vec![0.0f32; m * d];
        for (j, &tok) in toks.iter().enumerate() {
            let tok = tok as usize;
            if tok >= vocab {
                bail!("paged decode: token id {tok} out of vocab {vocab}");
            }
            h[j * d..(j + 1) * d].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
        }

        for i in 0..self.mm.dims.n_layers {
            let (x1, _) = rms_norm_fwd(&h, self.weight(&format!("L{i}.norm1")), m, d, eps);
            let mut q = gemm(&x1, self.weight(&format!("L{i}.wq")), m, d, d);
            let mut k = gemm(&x1, self.weight(&format!("L{i}.wk")), m, d, d);
            let v = gemm(&x1, self.weight(&format!("L{i}.wv")), m, d, d);
            for (j, (&r, &p)) in rows.iter().zip(&qpos).enumerate() {
                rope_row(&self.cos, &self.sin, &mut q[j * d..(j + 1) * d], heads, hd, p);
                rope_row(&self.cos, &self.sin, &mut k[j * d..(j + 1) * d], heads, hd, p);
                let table = &self.streams[r].as_ref().unwrap().table;
                let (block, slot) = (table[p / bt], p % bt);
                self.pool
                    .write(i, block, slot, &k[j * d..(j + 1) * d], &v[j * d..(j + 1) * d]);
            }
            let tables: Vec<&[u32]> = rows
                .iter()
                .map(|&r| self.streams[r].as_ref().unwrap().table.as_slice())
                .collect();
            let (kp, vp) = self.pool.layer_kv(i);
            let attn = attn_decode_paged(&q, kp, vp, &tables, &qpos, heads, hd, bt, scale);
            // h_mid = h + attn @ (wo + ΔWo) (residual add, same order as
            // forward; ΔWo only when an unfused adapter is set)
            let mut wo_out = gemm(&attn, self.weight(&format!("L{i}.wo")), m, d, d);
            if let Some(l) = self.unfused_layer(i) {
                apply_unfused_rows(&attn, &l.wo_rows, &l.wo_delta, m, d, d, &mut wo_out);
            }
            for (hv, ov) in h.iter_mut().zip(&wo_out) {
                *hv += ov;
            }
            let (x2, _) = rms_norm_fwd(&h, self.weight(&format!("L{i}.norm2")), m, d, eps);
            let u = gemm(&x2, self.weight(&format!("L{i}.wu")), m, d, ff);
            let g = gemm(&x2, self.weight(&format!("L{i}.wg")), m, d, ff);
            let mut act = vec![0.0f32; m * ff];
            for j in 0..m * ff {
                act[j] = u[j] * g[j] * sigmoid(g[j]);
            }
            let mut wd_out = gemm(&act, self.weight(&format!("L{i}.wd")), m, ff, d);
            if let Some(l) = self.unfused_layer(i) {
                apply_unfused_rows(&act, &l.wd_rows, &l.wd_delta, m, ff, d, &mut wd_out);
            }
            for (hv, ov) in h.iter_mut().zip(&wd_out) {
                *hv += ov;
            }
        }

        let (xf, _) = rms_norm_fwd(&h, self.weight("norm_f"), m, d, eps);
        let logits = gemm_nt(&xf, embed, m, d, vocab);
        for (j, &r) in rows.iter().enumerate() {
            out[r * vocab..(r + 1) * vocab].copy_from_slice(&logits[j * vocab..(j + 1) * vocab]);
            self.streams[r].as_mut().unwrap().pos += 1;
        }
        Ok(out)
    }

    fn pool_usage(&self) -> PoolUsage {
        self.pool.usage()
    }

    fn set_unfused_adapter(&mut self, adapter: Option<Arc<AnyAdapter>>) -> Result<()> {
        let Some(ad) = adapter else {
            self.unfused = None;
            return Ok(());
        };
        let AnyAdapter::S2ft(a) = ad.as_ref() else {
            bail!("unfused decode supports S²FT adapters only (LoRA must be fused)");
        };
        let d = self.mm.dims.d_model;
        let ff = self.mm.dims.d_ff;
        if a.layers.len() != self.mm.dims.n_layers {
            bail!(
                "unfused adapter has {} layers, model has {}",
                a.layers.len(),
                self.mm.dims.n_layers
            );
        }
        if a.d_model != d {
            bail!("unfused adapter d_model {} != model d_model {d}", a.d_model);
        }
        for (i, l) in a.layers.iter().enumerate() {
            for (proj, rows, delta, k) in [
                ("wo", &l.wo_rows, &l.wo_delta, d),
                ("wd", &l.wd_rows, &l.wd_delta, ff),
            ] {
                if let Some(&r) = rows.iter().max() {
                    if r >= k {
                        bail!("unfused adapter L{i}.{proj} row {r} out of bounds ({k} rows)");
                    }
                }
                if delta.len() != rows.len() * d {
                    bail!(
                        "unfused adapter L{i}.{proj} delta length {} != {} rows x d_model {d}",
                        delta.len(),
                        rows.len()
                    );
                }
            }
        }
        self.unfused = Some(ad);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::{LoraAdapter, S2ftAdapter};
    use crate::runtime::{Executable, Executor, NativeBackend};

    fn tiny_params() -> (NativeBackend, HashMap<String, Tensor>) {
        let rt = NativeBackend::builtin();
        let init = rt.load("init_tiny").unwrap();
        let outs = init.run(&[Tensor::scalar_i32(5)]).unwrap();
        let params: HashMap<String, Tensor> =
            init.spec().outputs.iter().map(|s| s.name.clone()).zip(outs).collect();
        (rt, params)
    }

    /// Model dims probed from the weight pool: (d_model, d_ff, n_layers).
    fn probe_dims(params: &HashMap<String, Tensor>) -> (usize, usize, usize) {
        let d = params["L0.wo"].shape[1];
        let ff = params["L0.wd"].shape[0];
        let n_layers =
            (0..).take_while(|i| params.contains_key(&format!("L{i}.wo"))).count();
        (d, ff, n_layers)
    }

    /// Small deterministic S²FT adapter touching two wo rows and two wd
    /// channels per layer.
    fn test_s2ft_adapter(params: &HashMap<String, Tensor>) -> S2ftAdapter {
        let (d, ff, n_layers) = probe_dims(params);
        let delta = |n: usize| -> Vec<f32> {
            (0..n).map(|j| ((j % 7) as f32 - 3.0) * 1e-3).collect()
        };
        let layers = (0..n_layers)
            .map(|_| S2ftLayerDelta {
                wo_rows: vec![0, d / 2],
                wo_delta: delta(2 * d),
                wd_rows: vec![1, ff / 2],
                wd_delta: delta(2 * d),
            })
            .collect();
        S2ftAdapter { layers, d_model: d }
    }

    /// The paged session must reproduce the contiguous session
    /// bit-for-bit under a staggered schedule with mid-flight admit /
    /// retire / slot-reuse churn — the core continuous-batching
    /// correctness contract.
    /// One co-scheduled tick: feed `(paged_row, ref_stream, token)`
    /// triples through the paged session and assert each row's logits
    /// match that stream's solo contiguous reference bit-for-bit.
    fn step_and_check(
        bt: usize,
        paged: &mut dyn PagedDecodeSession,
        refs: &mut [Box<dyn DecodeSession + '_>],
        feeds: &[(usize, usize, i32)],
    ) {
        let mut step = vec![None; 3];
        for &(row, _, tok) in feeds {
            step[row] = Some(tok);
        }
        let rows: Vec<usize> = feeds.iter().map(|f| f.0).collect();
        paged.reserve(&rows).unwrap();
        let got = paged.step(&step).unwrap();
        for &(row, rs, tok) in feeds {
            let want = refs[rs].step(&[Some(tok)]).unwrap();
            let g = &got[row * 261..(row + 1) * 261];
            assert!(
                want.iter().zip(g).all(|(x, y)| x.to_bits() == y.to_bits()),
                "paged row {row} drifted from reference stream {rs} (bt={bt})"
            );
        }
    }

    #[test]
    fn paged_session_matches_contiguous_under_churn() {
        let (rt, params) = tiny_params();
        let provider = rt.decoder().unwrap();
        let t_max = 12usize;
        let toks = |s: u64, i: usize| ((s * 37 + i as u64 * 11) % 256) as i32;
        for bt in [1usize, 3, 16] {
            let cfg = KvPoolConfig { block_tokens: bt, blocks: 0 };
            let mut paged = provider.open_paged("tiny", &params, 3, t_max, cfg).unwrap().unwrap();
            // reference: one contiguous session per stream (the schedule
            // below steps streams at different times; per-row logits must
            // not depend on co-scheduled rows)
            let mut refs: Vec<_> = (0..3)
                .map(|_| provider.open_session("tiny", &params, 1, t_max).unwrap())
                .collect();

            // stream 0 on row 0 (whole run), stream 1 on row 2 (retired
            // early), stream 2 re-uses row 2 after stream 1 retires
            paged.admit(0).unwrap();
            paged.admit(2).unwrap();
            for i in 0..4 {
                let feeds = [(0, 0, toks(0, i)), (2, 1, toks(1, i))];
                step_and_check(bt, paged.as_mut(), &mut refs, &feeds);
            }
            // stream 1 done: its blocks return to the pool; stream 2
            // takes over row 2 with a fresh table while stream 0 keeps
            // decoding — its bits must not move
            paged.retire(2);
            assert!(!paged.is_active(2));
            paged.admit(2).unwrap();
            for i in 0..5 {
                let feeds = [(0, 0, toks(0, 4 + i)), (2, 2, toks(2, i))];
                step_and_check(bt, paged.as_mut(), &mut refs, &feeds);
            }
            // solo ticks for stream 0 (rows step independently)
            for i in 0..3 {
                let feeds = [(0, 0, toks(0, 9 + i))];
                step_and_check(bt, paged.as_mut(), &mut refs, &feeds);
            }
            assert_eq!(paged.pos(0), 12);
            paged.retire(0);
            paged.retire(2);
            assert_eq!(paged.pool_usage().used_bytes, 0, "retire must reclaim all blocks");
        }
    }

    /// reserve() surfaces the typed pool error and leaves the session
    /// usable: retiring a stream frees enough blocks to continue.
    #[test]
    fn reserve_exhaustion_is_typed_and_recoverable() {
        let (rt, params) = tiny_params();
        let provider = rt.decoder().unwrap();
        // 2 blocks of 2 tokens: two streams exhaust the pool at pos 2
        let cfg = KvPoolConfig { block_tokens: 2, blocks: 2 };
        let mut sess = provider.open_paged("tiny", &params, 2, 8, cfg).unwrap().unwrap();
        sess.admit(0).unwrap();
        sess.admit(1).unwrap();
        for _ in 0..2 {
            sess.reserve(&[0, 1]).unwrap();
            sess.step(&[Some(1), Some(2)]).unwrap();
        }
        let err = sess.reserve(&[0, 1]).unwrap_err();
        assert_eq!(err.free_blocks, 0);
        assert_eq!(err.capacity_blocks, 2);
        sess.retire(1);
        sess.reserve(&[0]).unwrap();
        sess.step(&[Some(3), None]).unwrap();
        assert_eq!(sess.pos(0), 3);
    }

    /// Unfused application must agree numerically with fusing the same
    /// adapter into the weights (same math, different reduction grouping)
    /// and must be deterministic run-to-run. It must also actually change
    /// the logits relative to the base model.
    #[test]
    fn unfused_adapter_matches_fused_numerically() {
        let (rt, params) = tiny_params();
        let provider = rt.decoder().unwrap();
        let a = test_s2ft_adapter(&params);
        let mut fused_params = params.clone();
        a.apply(&mut fused_params).unwrap();

        let cfg = || KvPoolConfig { block_tokens: 4, blocks: 0 };
        let mut fused = provider.open_paged("tiny", &fused_params, 2, 8, cfg()).unwrap().unwrap();
        let mut base = provider.open_paged("tiny", &params, 2, 8, cfg()).unwrap().unwrap();
        let mut unfused = provider.open_paged("tiny", &params, 2, 8, cfg()).unwrap().unwrap();
        let mut unfused2 = provider.open_paged("tiny", &params, 2, 8, cfg()).unwrap().unwrap();
        let handle = Arc::new(AnyAdapter::S2ft(a));
        unfused.set_unfused_adapter(Some(handle.clone())).unwrap();
        unfused2.set_unfused_adapter(Some(handle)).unwrap();

        for s in [&mut fused, &mut base, &mut unfused, &mut unfused2] {
            s.admit(0).unwrap();
            s.admit(1).unwrap();
        }
        let toks = |i: usize, r: usize| ((i * 13 + r * 7 + 5) % 256) as i32;
        let mut adapter_moved_logits = false;
        for i in 0..6 {
            let feed = [Some(toks(i, 0)), Some(toks(i, 1))];
            let mut out = Vec::new();
            for s in [&mut fused, &mut base, &mut unfused, &mut unfused2] {
                s.reserve(&[0, 1]).unwrap();
                out.push(s.step(&feed).unwrap());
            }
            for (x, y) in out[0].iter().zip(&out[2]) {
                assert!(
                    (x - y).abs() <= 1e-3 + 1e-3 * x.abs(),
                    "fused {x} vs unfused {y} diverged at step {i}"
                );
            }
            adapter_moved_logits |=
                out[1].iter().zip(&out[2]).any(|(b, u)| b.to_bits() != u.to_bits());
            assert!(
                out[2].iter().zip(&out[3]).all(|(x, y)| x.to_bits() == y.to_bits()),
                "unfused application must be deterministic (step {i})"
            );
        }
        assert!(adapter_moved_logits, "unfused adapter had no effect on logits");
    }

    /// `set_unfused_adapter` validates against the model before
    /// accepting: LoRA, layer-count / d_model mismatches, out-of-bounds
    /// rows and short delta buffers are all rejected; `None` clears.
    #[test]
    fn set_unfused_adapter_validates() {
        let (rt, params) = tiny_params();
        let provider = rt.decoder().unwrap();
        let (d, ff, n_layers) = probe_dims(&params);
        let cfg = KvPoolConfig { block_tokens: 4, blocks: 0 };
        let mut sess = provider.open_paged("tiny", &params, 1, 8, cfg).unwrap().unwrap();

        let mk = |a: S2ftAdapter| Some(Arc::new(AnyAdapter::S2ft(a)));
        let lora = AnyAdapter::Lora(LoraAdapter { layers: vec![], scale: 1.0 });
        assert!(sess.set_unfused_adapter(Some(Arc::new(lora))).is_err(), "LoRA rejected");
        assert!(
            sess.set_unfused_adapter(mk(S2ftAdapter { layers: vec![], d_model: d })).is_err(),
            "layer-count mismatch rejected"
        );
        let good = test_s2ft_adapter(&params);
        let mut wrong_d = good.clone();
        wrong_d.d_model = d + 1;
        assert!(sess.set_unfused_adapter(mk(wrong_d)).is_err(), "d_model mismatch rejected");
        let mut oob = good.clone();
        oob.layers[0].wo_rows = vec![d];
        oob.layers[0].wo_delta = vec![0.0; d];
        assert!(sess.set_unfused_adapter(mk(oob)).is_err(), "wo row out of bounds rejected");
        let mut oob_wd = good.clone();
        oob_wd.layers[0].wd_rows = vec![ff];
        oob_wd.layers[0].wd_delta = vec![0.0; d];
        assert!(sess.set_unfused_adapter(mk(oob_wd)).is_err(), "wd row out of bounds rejected");
        let mut short = good.clone();
        short.layers[n_layers - 1].wd_delta.pop();
        assert!(sess.set_unfused_adapter(mk(short)).is_err(), "short delta rejected");

        sess.set_unfused_adapter(mk(good)).unwrap();
        sess.set_unfused_adapter(None).unwrap();
    }
}
