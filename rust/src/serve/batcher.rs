//! Dynamic batcher with adapter affinity.
//!
//! Groups queued requests by adapter id, emitting batches of at most
//! `max_batch`. Under [`SchedPolicy::AdapterAffinity`] it serves the
//! *largest* group first (throughput) but never starves: groups older
//! than `max_wait` get priority (bounded latency / backpressure).
//! [`SchedPolicy::Fifo`] always serves the oldest request's group.
//! Engine-pool workers call [`AdapterBatcher::next_batch_preferring`]
//! with their currently-fused adapter so a worker keeps draining "its"
//! adapter switch-free while other groups are fresh.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// How the batcher picks the next adapter group to serve.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedPolicy {
    /// Largest queued group first (amortizes adapter switches), with the
    /// `max_wait` starvation guard.
    #[default]
    AdapterAffinity,
    /// Strictly oldest request first (minimal queueing latency, more
    /// switches).
    Fifo,
}

/// One queued request: its routing adapter id, arrival time and payload.
#[derive(Debug, Clone)]
pub struct Queued<T> {
    /// Adapter id the request is routed to.
    pub adapter: String,
    /// Arrival time (drives the starvation guard and latency metrics).
    pub enqueued: Instant,
    /// The caller's request payload.
    pub payload: T,
}

/// A cut batch: `items` all share `adapter`, FIFO order preserved.
#[derive(Debug)]
pub struct BatchPlan<T> {
    /// The adapter every item in this plan is routed to.
    pub adapter: String,
    /// The batch, in arrival order (at most `max_batch` items).
    pub items: Vec<Queued<T>>,
}

/// The shared work queue: one FIFO of [`Queued`] requests plus the
/// grouping/starvation policy that cuts it into single-adapter batches.
pub struct AdapterBatcher<T> {
    queue: VecDeque<Queued<T>>,
    /// Most items a single [`BatchPlan`] may carry.
    pub max_batch: usize,
    /// Age past which a queued request overrides group-size scheduling.
    pub max_wait: Duration,
    /// Group-selection policy (see [`SchedPolicy`]).
    pub policy: SchedPolicy,
}

impl<T> AdapterBatcher<T> {
    /// Empty batcher with the default [`SchedPolicy::AdapterAffinity`].
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        Self {
            queue: VecDeque::new(),
            max_batch,
            max_wait,
            policy: SchedPolicy::AdapterAffinity,
        }
    }

    /// Builder-style policy override.
    pub fn with_policy(mut self, policy: SchedPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Enqueue one request for `adapter`, stamped with its arrival time.
    pub fn push(&mut self, adapter: impl Into<String>, payload: T) {
        self.queue.push_back(Queued {
            adapter: adapter.into(),
            enqueued: Instant::now(),
            payload,
        });
    }

    /// Number of queued requests.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// Whether the queue is empty.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Age of the oldest queued request (zero when empty). O(1): pushes
    /// append and [`Self::take_group`] preserves relative order, so the
    /// front of the queue is always the oldest entry.
    pub fn oldest_age(&self) -> Duration {
        self.queue
            .front()
            .map(|q| q.enqueued.elapsed())
            .unwrap_or(Duration::ZERO)
    }

    /// Whether any queued request has waited past `max_wait` (the front
    /// is the oldest, so checking it covers the whole queue).
    fn any_overdue(&self) -> bool {
        self.queue
            .front()
            .is_some_and(|q| q.enqueued.elapsed() >= self.max_wait)
    }

    /// Pick the adapter to serve next; None if the queue is empty.
    fn pick_adapter(&self) -> Option<String> {
        // starvation guard: oldest overdue request wins (under Fifo
        // everything counts as overdue)
        let overdue = self
            .queue
            .iter()
            .filter(|q| {
                self.policy == SchedPolicy::Fifo || q.enqueued.elapsed() >= self.max_wait
            })
            .min_by_key(|q| q.enqueued);
        if let Some(q) = overdue {
            return Some(q.adapter.clone());
        }
        // otherwise the largest group (throughput-optimal switch amortization)
        let mut counts: std::collections::HashMap<&str, usize> = Default::default();
        for q in &self.queue {
            *counts.entry(q.adapter.as_str()).or_default() += 1;
        }
        counts
            .into_iter()
            .max_by_key(|(_, c)| *c)
            .map(|(a, _)| a.to_string())
    }

    /// Remove and return the next batch (same adapter, FIFO within group).
    pub fn next_batch(&mut self) -> Option<BatchPlan<T>> {
        let adapter = self.pick_adapter()?;
        Some(self.take_group(adapter))
    }

    /// Like [`Self::next_batch`], but while nothing is overdue prefer
    /// `prefer` (the caller's currently-fused adapter) when it has queued
    /// requests — the switch-free fast path for engine-pool workers.
    pub fn next_batch_preferring(&mut self, prefer: Option<&str>) -> Option<BatchPlan<T>> {
        self.next_batch_preferring_where(prefer, |_| true)
    }

    /// [`Self::next_batch_preferring`] with a residency hint: while
    /// nothing is overdue, groups for which `resident` answers `true`
    /// (their adapter weights are already in memory) are picked before
    /// non-resident ones, largest-first within each class — so a worker
    /// only pays a lazy adapter load when no resident work is queued.
    /// The starvation guard and [`SchedPolicy::Fifo`] ignore the hint
    /// entirely: age still beats residency.
    pub fn next_batch_preferring_where(
        &mut self,
        prefer: Option<&str>,
        resident: impl Fn(&str) -> bool,
    ) -> Option<BatchPlan<T>> {
        if let Some(p) = prefer {
            let preferable = self.policy == SchedPolicy::AdapterAffinity
                && !self.any_overdue()
                && self.queue.iter().any(|q| q.adapter == p);
            if preferable {
                return Some(self.take_group(p.to_string()));
            }
        }
        if self.policy == SchedPolicy::AdapterAffinity && !self.any_overdue() {
            let mut counts: std::collections::HashMap<&str, usize> = Default::default();
            for q in &self.queue {
                *counts.entry(q.adapter.as_str()).or_default() += 1;
            }
            // ties (same residency, same size) break on adapter id, so
            // the choice never depends on hash-map iteration order
            let pick = counts
                .into_iter()
                .max_by_key(|(a, c)| (resident(a), *c, std::cmp::Reverse(*a)))
                .map(|(a, _)| a.to_string());
            return pick.map(|a| self.take_group(a));
        }
        self.next_batch()
    }

    /// Continuous-batching top-up: drain up to `max` queued requests for
    /// `adapter` (FIFO within the group, everything else keeps its slot)
    /// *without* picking a new group.
    ///
    /// Returns empty — telling the caller to end its run and go back
    /// through normal scheduling — when the scheduler would not pick
    /// `adapter` next: under [`SchedPolicy::AdapterAffinity`] when some
    /// other adapter's request is overdue, under [`SchedPolicy::Fifo`]
    /// whenever the oldest queued request belongs to another adapter.
    /// This mirrors the [`Self::next_batch_preferring`] starvation guard
    /// so a worker topping up a long-running batch cannot starve other
    /// adapters.
    pub fn take_matching(&mut self, adapter: &str, max: usize) -> Vec<Queued<T>> {
        if max == 0 || self.queue.is_empty() {
            return Vec::new();
        }
        let front_other = self.queue.front().is_some_and(|q| q.adapter != adapter);
        let yield_to_other = match self.policy {
            SchedPolicy::AdapterAffinity => front_other && self.any_overdue(),
            SchedPolicy::Fifo => front_other,
        };
        if yield_to_other {
            return Vec::new();
        }
        let mut items = Vec::with_capacity(max.min(self.queue.len()));
        let mut rest = VecDeque::with_capacity(self.queue.len());
        for q in self.queue.drain(..) {
            if q.adapter == adapter && items.len() < max {
                items.push(q);
            } else {
                rest.push_back(q);
            }
        }
        self.queue = rest;
        items
    }

    fn take_group(&mut self, adapter: String) -> BatchPlan<T> {
        let mut items = Vec::with_capacity(self.max_batch);
        let mut rest = VecDeque::with_capacity(self.queue.len());
        for q in self.queue.drain(..) {
            if q.adapter == adapter && items.len() < self.max_batch {
                items.push(q);
            } else {
                rest.push_back(q);
            }
        }
        self.queue = rest;
        BatchPlan { adapter, items }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_group_by_adapter_and_cap() {
        let mut b = AdapterBatcher::new(2, Duration::from_secs(60));
        b.push("a", 1);
        b.push("b", 2);
        b.push("a", 3);
        b.push("a", 4);
        let p = b.next_batch().unwrap();
        assert_eq!(p.adapter, "a");
        assert_eq!(p.items.len(), 2); // capped at max_batch
        assert_eq!(p.items[0].payload, 1);
        assert_eq!(p.items[1].payload, 3);
        assert_eq!(b.len(), 2);
        let p2 = b.next_batch().unwrap();
        // remaining 'a' (1 item) vs 'b' (1 item): either is fine, but FIFO
        // grouping must preserve payload order within the adapter.
        assert!(p2.items.len() == 1);
    }

    #[test]
    fn starvation_guard_prioritizes_old_requests() {
        let mut b = AdapterBatcher::new(4, Duration::from_millis(0)); // everything overdue
        b.push("old", 1);
        std::thread::sleep(Duration::from_millis(2));
        b.push("big", 2);
        b.push("big", 3);
        b.push("big", 4);
        let p = b.next_batch().unwrap();
        assert_eq!(p.adapter, "old"); // despite "big" being larger
    }

    #[test]
    fn largest_group_wins_when_fresh() {
        let mut b = AdapterBatcher::new(4, Duration::from_secs(60));
        b.push("a", 1);
        b.push("b", 2);
        b.push("b", 3);
        let p = b.next_batch().unwrap();
        assert_eq!(p.adapter, "b");
    }

    #[test]
    fn empty_queue_returns_none() {
        let mut b: AdapterBatcher<u32> = AdapterBatcher::new(4, Duration::from_secs(1));
        assert!(b.next_batch().is_none());
    }

    /// Affinity: a batch only ever contains one adapter, and skipped
    /// requests keep their FIFO slot for the next round.
    #[test]
    fn affinity_never_mixes_adapters() {
        let mut b = AdapterBatcher::new(8, Duration::from_secs(60));
        for i in 0..12 {
            b.push(format!("a{}", i % 3), i);
        }
        while let Some(plan) = b.next_batch() {
            assert!(plan.items.iter().all(|q| q.adapter == plan.adapter));
            assert!(
                plan.items.windows(2).all(|w| w[0].payload < w[1].payload),
                "FIFO order broken within {:?}",
                plan.adapter
            );
        }
    }

    /// A worker already fused on `b` keeps draining `b` while nothing is
    /// overdue, even though `a` is the larger group.
    #[test]
    fn preferring_keeps_fused_adapter_while_fresh() {
        let mut b = AdapterBatcher::new(4, Duration::from_secs(60));
        b.push("a", 1);
        b.push("a", 2);
        b.push("a", 3);
        b.push("b", 4);
        let p = b.next_batch_preferring(Some("b")).unwrap();
        assert_eq!(p.adapter, "b");
        assert_eq!(p.items[0].payload, 4);
        // preference for an adapter with nothing queued falls back
        let p2 = b.next_batch_preferring(Some("b")).unwrap();
        assert_eq!(p2.adapter, "a");
        // and no preference behaves exactly like next_batch
        assert!(b.next_batch_preferring(None).is_none());
    }

    /// Preference never overrides the starvation guard: once another
    /// group is overdue, the oldest request wins.
    #[test]
    fn preferring_yields_to_overdue_requests() {
        let mut b = AdapterBatcher::new(4, Duration::from_millis(1));
        b.push("old", 1);
        std::thread::sleep(Duration::from_millis(3));
        b.push("mine", 2);
        let p = b.next_batch_preferring(Some("mine")).unwrap();
        assert_eq!(p.adapter, "old");
    }

    /// Residency hint: resident groups are served before non-resident
    /// ones while fresh; preference, age and Fifo all override it.
    #[test]
    fn preferring_where_picks_resident_groups_first() {
        let mut b = AdapterBatcher::new(8, Duration::from_secs(60));
        b.push("big", 1);
        b.push("big", 2);
        b.push("big", 3);
        b.push("res", 4);
        let p = b.next_batch_preferring_where(None, |id| id == "res").unwrap();
        assert_eq!(p.adapter, "res", "resident beats the larger non-resident group");
        let p2 = b.next_batch_preferring_where(None, |id| id == "res").unwrap();
        assert_eq!(p2.adapter, "big", "without resident work, size wins as before");
        // the worker's fused adapter still wins over residency
        b.push("big", 5);
        b.push("res", 6);
        let p3 = b.next_batch_preferring_where(Some("big"), |id| id == "res").unwrap();
        assert_eq!(p3.adapter, "big");

        // overdue requests beat residency
        let mut o = AdapterBatcher::new(8, Duration::from_millis(1));
        o.push("old", 1);
        std::thread::sleep(Duration::from_millis(3));
        o.push("res", 2);
        let po = o.next_batch_preferring_where(None, |id| id == "res").unwrap();
        assert_eq!(po.adapter, "old");

        // Fifo ignores the hint
        let mut f =
            AdapterBatcher::new(8, Duration::from_secs(60)).with_policy(SchedPolicy::Fifo);
        f.push("a", 1);
        f.push("b", 2);
        let pf = f.next_batch_preferring_where(None, |id| id == "b").unwrap();
        assert_eq!(pf.adapter, "a");
    }

    /// Fifo policy: strictly oldest request's group first, group size is
    /// irrelevant, but batches still never mix adapters.
    #[test]
    fn fifo_policy_serves_oldest_group_first() {
        let mut b = AdapterBatcher::new(8, Duration::from_secs(60)).with_policy(SchedPolicy::Fifo);
        b.push("first", 0);
        std::thread::sleep(Duration::from_millis(1));
        b.push("big", 1);
        b.push("big", 2);
        b.push("big", 3);
        std::thread::sleep(Duration::from_millis(1));
        b.push("first", 4);
        let p = b.next_batch().unwrap();
        assert_eq!(p.adapter, "first");
        // FIFO batch still collects the whole group (affinity intact)
        assert_eq!(p.items.iter().map(|q| q.payload).collect::<Vec<_>>(), vec![0, 4]);
        // preference is ignored under Fifo
        b.push("late", 9);
        let p2 = b.next_batch_preferring(Some("late")).unwrap();
        assert_eq!(p2.adapter, "big");
    }

    /// An over-large group splits into consecutive max_batch chunks with
    /// FIFO payload order preserved end-to-end.
    #[test]
    fn oversized_group_splits_at_max_batch() {
        let mut b = AdapterBatcher::new(3, Duration::from_secs(60));
        for i in 0..8 {
            b.push("a", i);
        }
        let sizes: Vec<usize> = std::iter::from_fn(|| b.next_batch())
            .map(|p| {
                assert_eq!(p.adapter, "a");
                p.items.len()
            })
            .collect();
        assert_eq!(sizes, vec![3, 3, 2]);
    }

    /// Zero `max_wait` (the engine's `window = 0` configuration): every
    /// request is instantly overdue, so batches cut immediately in
    /// arrival order and `oldest_age` reflects the head of the queue.
    #[test]
    fn zero_window_cuts_immediately_in_arrival_order() {
        let mut b = AdapterBatcher::new(8, Duration::ZERO);
        assert_eq!(b.oldest_age(), Duration::ZERO);
        b.push("x", 1);
        std::thread::sleep(Duration::from_millis(1));
        b.push("y", 2);
        assert!(b.oldest_age() >= Duration::from_millis(1));
        let p = b.next_batch_preferring(Some("y")).unwrap();
        assert_eq!(p.adapter, "x", "zero window: age beats preference");
        assert_eq!(b.next_batch().unwrap().adapter, "y");
        assert!(b.next_batch().is_none());
    }

    /// Top-up path: takes only matching items, caps at `max`, preserves
    /// everyone else's FIFO slot.
    #[test]
    fn take_matching_drains_own_adapter_up_to_max() {
        let mut b = AdapterBatcher::new(8, Duration::from_secs(60));
        b.push("a", 1);
        b.push("b", 2);
        b.push("a", 3);
        b.push("a", 4);
        let got = b.take_matching("a", 2);
        assert_eq!(got.iter().map(|q| q.payload).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(b.len(), 2);
        // leftover keeps arrival order: b=2 first, then a=4
        let rest = b.take_matching("a", 8);
        assert_eq!(rest.iter().map(|q| q.payload).collect::<Vec<_>>(), vec![4]);
        assert_eq!(b.next_batch().unwrap().adapter, "b");
        assert!(b.take_matching("a", 4).is_empty(), "empty queue yields nothing");
        assert!(b.take_matching("a", 0).is_empty(), "max 0 yields nothing");
    }

    /// Top-up must respect the starvation guard: an overdue foreign
    /// request at the front ends the run (affinity), and under Fifo any
    /// foreign front does.
    #[test]
    fn take_matching_yields_to_starving_adapters() {
        let mut b = AdapterBatcher::new(8, Duration::from_millis(1));
        b.push("other", 1);
        b.push("mine", 2);
        std::thread::sleep(Duration::from_millis(3)); // "other" is overdue
        assert!(b.take_matching("mine", 8).is_empty());
        assert_eq!(b.len(), 2, "yielding must not consume the queue");

        let mut f =
            AdapterBatcher::new(8, Duration::from_secs(60)).with_policy(SchedPolicy::Fifo);
        f.push("other", 1);
        f.push("mine", 2);
        assert!(f.take_matching("mine", 8).is_empty(), "Fifo yields to any foreign front");
        f.push("late", 3);
        let own = f.take_matching("other", 8);
        assert_eq!(own.len(), 1, "own front is takeable under Fifo");
    }

    /// Windowing: once the wait budget expires, age dominates group size —
    /// and within the overdue set, the *oldest* adapter is served first.
    #[test]
    fn windowing_prefers_oldest_once_overdue() {
        let mut b = AdapterBatcher::new(8, Duration::from_millis(1));
        b.push("first", 0);
        std::thread::sleep(Duration::from_millis(3));
        b.push("second", 1);
        b.push("big", 2);
        b.push("big", 3);
        b.push("big", 4);
        std::thread::sleep(Duration::from_millis(3)); // all overdue now
        let p1 = b.next_batch().unwrap();
        assert_eq!(p1.adapter, "first");
        let p2 = b.next_batch().unwrap();
        assert_eq!(p2.adapter, "second");
        let p3 = b.next_batch().unwrap();
        assert_eq!(p3.adapter, "big");
        assert_eq!(p3.items.len(), 3);
    }
}
