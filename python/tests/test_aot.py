"""AOT pipeline: HLO text artifacts round-trip through the XLA CPU client.

This exercises the same interchange path rust uses (HLO text -> parse ->
compile -> execute), so a failure here localizes bridge bugs before
touching rust.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc
from jax._src.interpreters import mlir as jmlir
from jax._src.lib.mlir import ir
from jaxlib._jax import DeviceList

from compile import model as M
from compile.configs import MODELS, default_methods
from compile.aot import to_hlo_text

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _have_artifacts():
    return os.path.exists(os.path.join(ART, "meta.json"))


def execute_hlo_text(text: str, args):
    """Parse HLO text and execute on the CPU PJRT client (rust-equivalent)."""
    hm = xc._xla.hlo_module_from_text(text)
    mlir_bc = xc._xla.mlir.hlo_to_stablehlo(hm.as_serialized_hlo_module_proto())
    with jmlir.make_ir_context():
        mod = ir.Module.parse(mlir_bc)
        backend = jax.devices()[0].client
        exe = backend.compile_and_load(mod, DeviceList(tuple(jax.devices())))
        out = exe.execute_sharded([jnp.asarray(a) for a in args])
        return [np.asarray(a[0]) for a in out.disassemble_into_single_device_arrays()]


def test_to_hlo_text_roundtrip_numerics():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    s = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(s, s))
    assert "ENTRY" in text
    x = np.array([[1, 2], [3, 4]], np.float32)
    y = np.ones((2, 2), np.float32)
    (got,) = execute_hlo_text(text, [x, y])
    np.testing.assert_allclose(got, x @ y + 2.0)


def test_hlo_text_parses_for_pallas_lowering():
    """interpret=True Pallas lowers to plain HLO the 0.5.1 parser accepts."""
    from compile.kernels.partial_update import matmul

    def fn(x, y):
        return (matmul(x, y),)

    s = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(s, s))
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()
    rng = np.random.default_rng(0)
    x = rng.standard_normal((8, 8)).astype(np.float32)
    y = rng.standard_normal((8, 8)).astype(np.float32)
    (got,) = execute_hlo_text(text, [x, y])
    np.testing.assert_allclose(got, x @ y, rtol=2e-5, atol=2e-5)


@pytest.mark.skipif(not _have_artifacts(), reason="run `make artifacts` first")
def test_meta_json_schema():
    meta = json.load(open(os.path.join(ART, "meta.json")))
    assert "models" in meta and "artifacts" in meta
    assert "tiny" in meta["models"]
    tiny = meta["models"]["tiny"]
    assert tiny["param_count"] == MODELS["tiny"].param_count()
    for mname, m in tiny["methods"].items():
        for sect in ("trainable", "frozen", "perms", "aux", "opt"):
            assert sect in m, (mname, sect)
    for aname, art in meta["artifacts"].items():
        assert os.path.exists(os.path.join(ART, art["file"])), aname
        for n, shape, dt in art["inputs"] + art["outputs"]:
            assert dt in ("f32", "i32")
            assert isinstance(shape, list)


@pytest.mark.skipif(not _have_artifacts(), reason="run `make artifacts` first")
@pytest.mark.parametrize("method", ["s2ft", "s2ft-pallas", "lora", "fullft"])
def test_train_artifact_matches_eager(method):
    """Execute train_tiny_* via the HLO-text path and compare against the
    eager train_step — the definitive L2<->artifact check."""
    meta = json.load(open(os.path.join(ART, "meta.json")))
    name = f"train_tiny_{method}_2x32"
    if name not in meta["artifacts"]:
        pytest.skip(f"{name} not built")
    art = meta["artifacts"][name]
    text = open(os.path.join(ART, art["file"])).read()

    cfg = MODELS["tiny"]
    mc = default_methods(cfg)[method]
    base = M.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (2, 32), 0, cfg.vocab).astype(jnp.int32)
    targets = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones((2, 32), jnp.float32)
    trn, frz, perms = M.prepare_method(cfg, mc, base, jnp.int32(5), tokens,
                                       targets, mask)
    oshapes = M.opt_state_shapes(cfg, mc)
    om = {k: jnp.zeros(v, jnp.float32) for k, v in oshapes.items()}
    ov = {k: jnp.zeros(v, jnp.float32) for k, v in oshapes.items()}

    nt, nm, nv, loss = M.train_step(cfg, mc, trn, frz, om, ov, jnp.float32(0),
                                    tokens, targets, mask, {})

    pools = dict(trn)
    pools.update(frz)
    pools.update({f"m.{k}": v for k, v in om.items()})
    pools.update({f"v.{k}": v for k, v in ov.items()})
    pools["step"] = jnp.float32(0)
    pools["tokens"], pools["targets"], pools["loss_mask"] = tokens, targets, mask
    args = [np.asarray(pools[n]) for n, _, _ in art["inputs"]]
    outs = execute_hlo_text(text, args)
    out_names = [n for n, _, _ in art["outputs"]]
    got_loss = float(outs[out_names.index("loss")])
    np.testing.assert_allclose(got_loss, float(loss), rtol=1e-4, atol=1e-5)
    k0 = sorted(trn)[0]
    got0 = outs[out_names.index(f"new.{k0}")]
    np.testing.assert_allclose(got0, np.asarray(nt[k0]), rtol=1e-4, atol=1e-5)


@pytest.mark.skipif(not _have_artifacts(), reason="run `make artifacts` first")
def test_prepare_then_merge_artifacts_roundtrip():
    """prepare -> merge through the artifacts reproduces the base params."""
    meta = json.load(open(os.path.join(ART, "meta.json")))
    pname, mname = "prepare_tiny_s2ft_2x32", "merge_tiny_s2ft"
    if pname not in meta["artifacts"]:
        pytest.skip("tiny s2ft artifacts not built")
    cfg = MODELS["tiny"]
    base = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.zeros((2, 32), jnp.int32)
    mask = jnp.ones((2, 32), jnp.float32)

    part = meta["artifacts"][pname]
    pools = dict(base)
    pools.update({"seed": jnp.int32(5), "tokens": tokens, "targets": tokens,
                  "loss_mask": mask})
    args = [np.asarray(pools[n]) for n, _, _ in part["inputs"]]
    pouts = execute_hlo_text(open(os.path.join(ART, part["file"])).read(), args)
    pout_names = [n for n, _, _ in part["outputs"]]

    mart = meta["artifacts"][mname]
    by_name = dict(zip(pout_names, pouts))
    margs = [by_name[n] for n, _, _ in mart["inputs"]]
    mouts = execute_hlo_text(open(os.path.join(ART, mart["file"])).read(), margs)
    mout_names = [n for n, _, _ in mart["outputs"]]
    for n, got in zip(mout_names, mouts):
        np.testing.assert_allclose(got, np.asarray(base[n]), rtol=2e-4, atol=2e-4,
                                   err_msg=n)
