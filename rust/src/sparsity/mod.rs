//! Selection/permutation bookkeeping — the rust mirror of
//! `python/compile/permute.py` conventions.
//!
//! Weight convention: `y = x @ W`, `W: (d_in, d_out)`. FFN channel `c` is
//! column `c` of wu/wg and row `c` of wd; MHA head `h` is column block `h`
//! of wq/wk/wv and row block `h` of wo. The prepare artifact outputs
//! trainable-first permutations (`L{i}.head_perm`, `L{i}.chan_perm`); this
//! module interprets them for adapter extraction and fusion.
//!
//! [`strategy`] builds on these primitives to make the *selection* step
//! itself pluggable (static S²FT vs. dynamic re-selection mid-run).

use std::collections::HashMap;

use anyhow::{bail, Result};

/// Pluggable unit-selection strategies (static S²FT, drop/grow,
/// grad-norm warmup) and the shared selection/score primitives.
pub mod strategy;

/// Mirror of python `selection.budget_to_counts`: per-projection trainable
/// fractions -> integer unit counts. Head-grouped projections
/// (wq/wk/wv/wo) count heads; channel projections (wu/wg/wd) count FFN
/// channels. A positive fraction always yields at least one unit; fractions
/// at or above 1.0 saturate at the unit total (`n_heads` / `d_ff`) so
/// downstream selections can never index out of range.
pub fn budget_to_counts(
    fractions: &HashMap<String, f64>,
    d_ff: usize,
    n_heads: usize,
) -> HashMap<String, usize> {
    let mut counts = HashMap::new();
    for (proj, &f) in fractions {
        let total = match proj.as_str() {
            "wo" | "wq" | "wk" | "wv" => n_heads,
            _ => d_ff,
        };
        let c = if f > 0.0 {
            ((f * total as f64).round() as usize).max(1).min(total)
        } else {
            0
        };
        counts.insert(proj.clone(), c);
    }
    counts
}

/// Permutation placing `selected` first (matching python
/// `trainable_first_permutation`).
pub fn trainable_first_permutation(selected: &[usize], total: usize) -> Result<Vec<usize>> {
    let mut seen = vec![false; total];
    for &c in selected {
        if c >= total {
            bail!("selection {c} out of range {total}");
        }
        if seen[c] {
            bail!("duplicate selection {c}");
        }
        seen[c] = true;
    }
    let mut perm = selected.to_vec();
    perm.extend((0..total).filter(|&c| !seen[c]));
    Ok(perm)
}

/// Inverse permutation: `inv[perm[i]] = i`.
pub fn invert_permutation(perm: &[usize]) -> Vec<usize> {
    let mut inv = vec![0usize; perm.len()];
    for (i, &p) in perm.iter().enumerate() {
        inv[p] = i;
    }
    inv
}

/// Expand a head-level permutation to element level (blocks of `head_dim`).
pub fn expand_head_perm(head_perm: &[usize], head_dim: usize) -> Vec<usize> {
    head_perm
        .iter()
        .flat_map(|&h| (0..head_dim).map(move |j| h * head_dim + j))
        .collect()
}

/// The selected unit ids: the first `count` entries of a trainable-first
/// permutation (as produced by the prepare artifact).
///
/// Invariant: `perm` must be *trainable-first* — `perm[..count]` are the
/// original unit indices chosen for training (in selection order) and
/// `perm[count..]` the frozen remainder, exactly as built by
/// [`trainable_first_permutation`]. The returned ids are therefore keyed by
/// *original* unit index, not permuted position — the key the optimizer-state
/// carry-over in replanning relies on.
pub fn selected_units(perm: &[usize], count: usize) -> Vec<usize> {
    perm[..count].to_vec()
}

/// Gather rows of a row-major `(rows, cols)` matrix at `idx`.
pub fn gather_rows(w: &[f32], cols: usize, idx: &[usize]) -> Vec<f32> {
    let mut out = Vec::with_capacity(idx.len() * cols);
    for &r in idx {
        out.extend_from_slice(&w[r * cols..(r + 1) * cols]);
    }
    out
}

/// Scatter-add rows into a row-major `(rows, cols)` matrix at `idx`.
///
/// This is the S²FT adapter *switch* primitive (paper Fig. 6): applying or
/// removing an adapter touches only `s * cols` elements — no GEMM.
pub fn scatter_add_rows(w: &mut [f32], cols: usize, idx: &[usize], delta: &[f32]) {
    debug_assert_eq!(delta.len(), idx.len() * cols);
    for (k, &r) in idx.iter().enumerate() {
        let dst = &mut w[r * cols..(r + 1) * cols];
        let src = &delta[k * cols..(k + 1) * cols];
        for (d, s) in dst.iter_mut().zip(src) {
            *d += *s;
        }
    }
}

/// Scatter-subtract (adapter unfuse).
pub fn scatter_sub_rows(w: &mut [f32], cols: usize, idx: &[usize], delta: &[f32]) {
    for (k, &r) in idx.iter().enumerate() {
        let dst = &mut w[r * cols..(r + 1) * cols];
        let src = &delta[k * cols..(k + 1) * cols];
        for (d, s) in dst.iter_mut().zip(src) {
            *d -= *s;
        }
    }
}

/// Gather columns of a row-major `(rows, cols)` matrix at `idx`.
pub fn gather_cols(w: &[f32], rows: usize, cols: usize, idx: &[usize]) -> Vec<f32> {
    let mut out = Vec::with_capacity(rows * idx.len());
    for r in 0..rows {
        for &c in idx {
            out.push(w[r * cols + c]);
        }
    }
    out
}

/// Scatter-add columns into a row-major `(rows, cols)` matrix.
pub fn scatter_add_cols(w: &mut [f32], rows: usize, cols: usize, idx: &[usize], delta: &[f32]) {
    debug_assert_eq!(delta.len(), rows * idx.len());
    for r in 0..rows {
        for (k, &c) in idx.iter().enumerate() {
            w[r * cols + c] += delta[r * idx.len() + k];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perm_roundtrip() {
        let perm = trainable_first_permutation(&[3, 1], 5).unwrap();
        assert_eq!(perm, vec![3, 1, 0, 2, 4]);
        let inv = invert_permutation(&perm);
        for i in 0..5 {
            assert_eq!(inv[perm[i]], i);
        }
    }

    #[test]
    fn perm_rejects_bad_input() {
        assert!(trainable_first_permutation(&[5], 5).is_err());
        assert!(trainable_first_permutation(&[1, 1], 5).is_err());
    }

    #[test]
    fn head_expansion() {
        assert_eq!(expand_head_perm(&[2, 0], 2), vec![4, 5, 0, 1]);
    }

    #[test]
    fn budget_counts_clamped_to_unit_total() {
        // Regression: fractions > 1.0 used to produce counts exceeding
        // n_heads / d_ff, yielding out-of-range selections downstream.
        let mut fr = HashMap::new();
        fr.insert("wo".to_string(), 1.5);
        fr.insert("wd".to_string(), 7.25);
        fr.insert("wu".to_string(), 1.0);
        fr.insert("wq".to_string(), 0.0);
        let counts = budget_to_counts(&fr, 16, 4);
        assert_eq!(counts["wo"], 4);
        assert_eq!(counts["wd"], 16);
        assert_eq!(counts["wu"], 16);
        assert_eq!(counts["wq"], 0);
    }

    #[test]
    fn selected_units_trainable_prefix() {
        let perm = trainable_first_permutation(&[3, 1], 5).unwrap();
        assert_eq!(selected_units(&perm, 2), vec![3, 1]);
        assert_eq!(selected_units(&perm, 0), Vec::<usize>::new());
    }

    #[test]
    fn gather_scatter_rows_roundtrip() {
        let mut w = vec![0.0f32; 12]; // 4x3
        let delta = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        scatter_add_rows(&mut w, 3, &[1, 3], &delta);
        assert_eq!(&w[3..6], &[1.0, 2.0, 3.0]);
        assert_eq!(&w[9..12], &[4.0, 5.0, 6.0]);
        assert_eq!(gather_rows(&w, 3, &[1, 3]), delta);
        scatter_sub_rows(&mut w, 3, &[1, 3], &delta);
        assert!(w.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn gather_scatter_cols_roundtrip() {
        let mut w = vec![0.0f32; 12]; // 3x4
        let delta = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 3x2
        scatter_add_cols(&mut w, 3, 4, &[0, 2], &delta);
        assert_eq!(gather_cols(&w, 3, 4, &[0, 2]), delta);
        assert_eq!(w[0], 1.0);
        assert_eq!(w[2], 2.0);
        assert_eq!(w[4 + 0], 3.0);
    }
}
