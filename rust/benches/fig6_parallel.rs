//! Figure 6c: multi-adapter parallel serving on a single linear layer.
//!
//! Every request in the batch uses a different adapter. Both paths share
//! the base GEMM; LoRA pays two chained small GEMVs per request, S²FT one
//! gather + dense delta pass. Sweep the number of concurrent adapters.

// s2ft-analyze: allow(bench-baseline) reason="paper-figure sweep, not a regression lane; medians depend on the sweep dims so no baseline is committed"
use repro::adapter::parallel::{
    base_forward, lora_parallel, s2ft_parallel, LoraReqAdapter, S2ftReqAdapter,
};
use repro::linalg::Mat;
use repro::util::bench::{black_box, BenchSuite};
use repro::util::rng::Rng;

fn main() {
    let mut suite = BenchSuite::new("fig6_parallel");
    let d = 1024usize;
    let k = 1024usize;
    let rank = 16usize;
    let sparsity = 32usize; // = 2r, the paper's parameter-matched setting
    println!(
        "Fig 6c: adapter parallelism on one ({k} x {d}) layer; LoRA r={rank}, S2FT s={sparsity}\n"
    );

    for n_adapters in [1usize, 4, 16, 64] {
        let mut rng = Rng::seed(n_adapters as u64);
        let x = Mat::randn(n_adapters, k, &mut rng);
        let w = Mat::randn(k, d, &mut rng);

        let loras: Vec<LoraReqAdapter> = (0..n_adapters)
            .map(|_| LoraReqAdapter {
                a: Mat::randn(k, rank, &mut rng),
                b: Mat::randn(rank, d, &mut rng),
                scale: 2.0,
            })
            .collect();
        let s2fts: Vec<S2ftReqAdapter> = (0..n_adapters)
            .map(|_| S2ftReqAdapter {
                rows: rng.choose(k, sparsity),
                delta: Mat::randn(sparsity, d, &mut rng),
            })
            .collect();

        suite.bench(&format!("lora_parallel/n={n_adapters}"), || {
            let mut y = base_forward(&x, &w);
            lora_parallel(&x, &mut y, &loras);
            black_box(y.data[0]);
        });
        suite.bench(&format!("s2ft_parallel/n={n_adapters}"), || {
            let mut y = base_forward(&x, &w);
            s2ft_parallel(&x, &mut y, &s2fts);
            black_box(y.data[0]);
        });
        // delta-only cost (base GEMM excluded), isolating the adapter math
        let mut y0 = base_forward(&x, &w);
        suite.bench(&format!("lora_delta_only/n={n_adapters}"), || {
            lora_parallel(&x, &mut y0, &loras);
            black_box(y0.data[0]);
        });
        suite.bench(&format!("s2ft_delta_only/n={n_adapters}"), || {
            s2ft_parallel(&x, &mut y0, &s2fts);
            black_box(y0.data[0]);
        });
    }
    println!("\nPaper shape: S²FT up to ~22% faster end-to-end, gap grows with adapter count.");
    suite.save();
}
