//! A small comment/string-aware Rust tokenizer for the `repro analyze`
//! lints.
//!
//! This is *not* a full Rust lexer — it only needs to be precise about
//! the things the lints care about: comments (line/block, doc or not,
//! with line spans), string/char literals (so lint patterns inside
//! strings are never mistaken for code), float vs integer literals, and
//! identifier boundaries. Everything else degrades to single-character
//! punctuation tokens, which is all the lint passes consume.
//!
//! Handled precisely: nested block comments, raw strings (`r"…"`,
//! `r#"…"#`), byte strings and byte chars, char-vs-lifetime
//! disambiguation, numeric literals (`0x1E` is an int, `1e3` and `1f32`
//! are floats, `0..n` is two ints and a range), and the multi-character
//! operators the lints match on (`==`, `!=`, `::`).

/// Token classes the lint passes distinguish.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`unsafe`, `HashMap`, `mul_add`, …).
    Ident,
    /// Integer literal, including hex/octal/binary forms.
    Int,
    /// Float literal (`0.0`, `1e3`, `2.`, `1f32`).
    Float,
    /// String literal of any flavor; `text` holds the (roughly
    /// unescaped) contents without quotes.
    Str,
    /// Char or byte-char literal.
    Char,
    /// Lifetime or loop label (`'a`, `'static`, `'outer`).
    Lifetime,
    /// Punctuation; multi-char only for `==`, `!=`, `::`.
    Punct,
}

/// One lexed token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
}

/// One comment, kept out of the token stream so lint patterns never
/// match commented-out code.
#[derive(Debug, Clone)]
pub struct Comment {
    /// Contents without the comment markers.
    pub text: String,
    /// 1-based line the comment starts on.
    pub line: usize,
    /// 1-based line the comment ends on (equals `line` for line
    /// comments).
    pub end_line: usize,
    /// `///`, `//!`, `/** … */` or `/*! … */`.
    pub doc: bool,
}

/// Result of [`lex`]: the token stream plus the comment side channel.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Tok>,
    pub comments: Vec<Comment>,
}

fn is_ident_start(c: char) -> bool {
    c == '_' || c.is_alphabetic()
}

fn is_ident_char(c: char) -> bool {
    c == '_' || c.is_alphanumeric()
}

/// Tokenize `src`. Never fails: unrecognized bytes become single-char
/// punctuation, unterminated literals end at end-of-file.
pub fn lex(src: &str) -> Lexed {
    let c: Vec<char> = src.chars().collect();
    let n = c.len();
    let mut i = 0usize;
    let mut line = 1usize;
    let mut out = Lexed::default();

    let at = |i: usize, ch: char| i < n && c[i] == ch;

    while i < n {
        let ch = c[i];
        if ch == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if ch.is_whitespace() {
            i += 1;
            continue;
        }

        // ---- line comments ------------------------------------------------
        if ch == '/' && at(i + 1, '/') {
            let mut j = i + 2;
            // `///x` and `//!x` are docs, but `////…` is a plain comment
            let doc = (at(j, '/') && !at(j + 1, '/')) || at(j, '!');
            if doc {
                j += 1;
            }
            let start = j;
            while j < n && c[j] != '\n' {
                j += 1;
            }
            let text: String = c[start..j].iter().collect();
            out.comments.push(Comment { text, line, end_line: line, doc });
            i = j;
            continue;
        }

        // ---- block comments (nested) --------------------------------------
        if ch == '/' && at(i + 1, '*') {
            let start_line = line;
            let mut j = i + 2;
            let doc = (at(j, '*') && !at(j + 1, '/')) || at(j, '!');
            let text_start = j;
            let mut depth = 1usize;
            while j < n && depth > 0 {
                if c[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if c[j] == '/' && at(j + 1, '*') {
                    depth += 1;
                    j += 2;
                } else if c[j] == '*' && at(j + 1, '/') {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            let text_end = if depth == 0 { j - 2 } else { j };
            let text: String = c[text_start..text_end.max(text_start)].iter().collect();
            out.comments.push(Comment { text, line: start_line, end_line: line, doc });
            i = j;
            continue;
        }

        // ---- raw strings: r"…", r#"…"#, br#"…"# ---------------------------
        if ch == 'r' || (ch == 'b' && at(i + 1, 'r')) {
            let p = if ch == 'r' { i + 1 } else { i + 2 };
            let mut hashes = 0usize;
            while at(p + hashes, '#') {
                hashes += 1;
            }
            if at(p + hashes, '"') {
                let start_line = line;
                let mut j = p + hashes + 1;
                let text_start = j;
                let mut text_end = n;
                while j < n {
                    if c[j] == '\n' {
                        line += 1;
                        j += 1;
                        continue;
                    }
                    if c[j] == '"' {
                        let mut h = 0usize;
                        while h < hashes && at(j + 1 + h, '#') {
                            h += 1;
                        }
                        if h == hashes {
                            text_end = j;
                            j += 1 + hashes;
                            break;
                        }
                    }
                    j += 1;
                }
                let text: String = c[text_start..text_end.min(n)].iter().collect();
                out.tokens.push(Tok { kind: TokKind::Str, text, line: start_line });
                i = j;
                continue;
            }
            // plain identifier starting with r/b — fall through below
        }

        // ---- byte string / byte char --------------------------------------
        if ch == 'b' && (at(i + 1, '"') || at(i + 1, '\'')) {
            // skip the `b` prefix and lex the literal itself
            i += 1;
            if c[i] == '"' {
                let (tok, ni, nl) = lex_string(&c, i, line);
                out.tokens.push(tok);
                i = ni;
                line = nl;
            } else {
                let (tok, ni) = lex_char(&c, i, line);
                out.tokens.push(tok);
                i = ni;
            }
            continue;
        }

        // ---- string literal ------------------------------------------------
        if ch == '"' {
            let (tok, ni, nl) = lex_string(&c, i, line);
            out.tokens.push(tok);
            i = ni;
            line = nl;
            continue;
        }

        // ---- char literal vs lifetime --------------------------------------
        if ch == '\'' {
            if at(i + 1, '\\') || (i + 2 < n && c[i + 2] == '\'' && c[i + 1] != '\'') {
                let (tok, ni) = lex_char(&c, i, line);
                out.tokens.push(tok);
                i = ni;
            } else {
                let mut j = i + 1;
                while j < n && is_ident_char(c[j]) {
                    j += 1;
                }
                let text: String = c[i..j].iter().collect();
                out.tokens.push(Tok { kind: TokKind::Lifetime, text, line });
                i = j;
            }
            continue;
        }

        // ---- numeric literal -----------------------------------------------
        if ch.is_ascii_digit() {
            let (tok, ni) = lex_number(&c, i, line);
            out.tokens.push(tok);
            i = ni;
            continue;
        }

        // ---- identifier / keyword ------------------------------------------
        if is_ident_start(ch) {
            let mut j = i + 1;
            while j < n && is_ident_char(c[j]) {
                j += 1;
            }
            let text: String = c[i..j].iter().collect();
            out.tokens.push(Tok { kind: TokKind::Ident, text, line });
            i = j;
            continue;
        }

        // ---- punctuation ----------------------------------------------------
        let eq_like = (ch == '=' || ch == '!') && at(i + 1, '=');
        let two = eq_like || (ch == ':' && at(i + 1, ':'));
        let len = if two { 2 } else { 1 };
        let text: String = c[i..i + len].iter().collect();
        out.tokens.push(Tok { kind: TokKind::Punct, text, line });
        i += len;
    }

    out
}

/// Lex a normal (or byte) string starting at the opening quote.
/// Returns the token, the index past the closing quote, and the updated
/// line counter.
fn lex_string(c: &[char], start: usize, mut line: usize) -> (Tok, usize, usize) {
    let n = c.len();
    let start_line = line;
    let mut j = start + 1;
    let mut text = String::new();
    while j < n {
        match c[j] {
            '"' => {
                j += 1;
                break;
            }
            '\n' => {
                line += 1;
                text.push('\n');
                j += 1;
            }
            '\\' if j + 1 < n => {
                let e = c[j + 1];
                match e {
                    'n' => text.push('\n'),
                    't' => text.push('\t'),
                    'r' => text.push('\r'),
                    '0' => text.push('\0'),
                    '\n' => line += 1, // line-continuation: swallow
                    'u' => {
                        // \u{…}: copy raw, advance to the brace close
                        text.push('\\');
                        text.push('u');
                        let mut k = j + 2;
                        while k < n && c[k] != '}' && c[k] != '\n' {
                            text.push(c[k]);
                            k += 1;
                        }
                        if k < n && c[k] == '}' {
                            text.push('}');
                            k += 1;
                        }
                        j = k;
                        continue;
                    }
                    other => text.push(other),
                }
                j += 2;
            }
            other => {
                text.push(other);
                j += 1;
            }
        }
    }
    (Tok { kind: TokKind::Str, text, line: start_line }, j, line)
}

/// Lex a char (or byte-char) literal starting at the opening quote.
/// The caller has already decided this is a char, not a lifetime.
fn lex_char(c: &[char], start: usize, line: usize) -> (Tok, usize) {
    let n = c.len();
    let mut j = start + 1;
    let text_start = j;
    if j < n && c[j] == '\\' {
        j += 1;
        if j < n && c[j] == 'u' {
            while j < n && c[j] != '}' && c[j] != '\n' {
                j += 1;
            }
            if j < n && c[j] == '}' {
                j += 1;
            }
        } else if j < n {
            j += 1;
        }
    } else if j < n {
        j += 1;
    }
    let text: String = c[text_start..j].iter().collect();
    if j < n && c[j] == '\'' {
        j += 1;
    }
    (Tok { kind: TokKind::Char, text, line }, j)
}

/// Lex a numeric literal starting at a digit. Distinguishes floats from
/// ints: a fractional part, an exponent, or an `f32`/`f64` suffix makes
/// a float; `0x…` hex digits never start an exponent; `0..n` leaves the
/// range dots alone; `1.max(2)` stays an int (the dot starts a method
/// call, not a fraction).
fn lex_number(c: &[char], start: usize, line: usize) -> (Tok, usize) {
    let n = c.len();
    let mut j = start;
    let mut float = false;

    if c[j] == '0' && j + 1 < n && matches!(c[j + 1], 'x' | 'o' | 'b') {
        j += 2;
        while j < n && (c[j].is_ascii_alphanumeric() || c[j] == '_') {
            j += 1;
        }
        let text: String = c[start..j].iter().collect();
        return (Tok { kind: TokKind::Int, text, line }, j);
    }

    while j < n && (c[j].is_ascii_digit() || c[j] == '_') {
        j += 1;
    }
    if j < n && c[j] == '.' {
        let after = c.get(j + 1).copied();
        let dot_is_fraction = match after {
            Some(a) => a.is_ascii_digit() || !(a == '.' || is_ident_start(a)),
            None => true,
        };
        if dot_is_fraction {
            float = true;
            j += 1;
            while j < n && (c[j].is_ascii_digit() || c[j] == '_') {
                j += 1;
            }
        }
    }
    if j < n && matches!(c[j], 'e' | 'E') {
        let mut k = j + 1;
        if k < n && matches!(c[k], '+' | '-') {
            k += 1;
        }
        if k < n && c[k].is_ascii_digit() {
            float = true;
            j = k;
            while j < n && (c[j].is_ascii_digit() || c[j] == '_') {
                j += 1;
            }
        }
    }
    // type suffix (i32, u8, f32, usize, …)
    let suffix_start = j;
    while j < n && is_ident_char(c[j]) {
        j += 1;
    }
    let suffix: String = c[suffix_start..j].iter().collect();
    if suffix.starts_with("f32") || suffix.starts_with("f64") {
        float = true;
    }
    let kind = if float { TokKind::Float } else { TokKind::Int };
    let text: String = c[start..j].iter().collect();
    (Tok { kind, text, line }, j)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).tokens.into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn comments_leave_the_token_stream() {
        let lx = lex("let x = 1; // trailing == 0.0\n/* block\n== 0.0 */ let y;");
        for t in &lx.tokens {
            assert!(!(t.kind == TokKind::Punct && t.text == "=="), "{}", t.text);
        }
        assert_eq!(lx.comments.len(), 2);
        assert!(lx.comments[0].text.contains("trailing == 0.0"));
        assert!(!lx.comments[0].doc);
        assert_eq!(lx.comments[1].line, 2);
        assert_eq!(lx.comments[1].end_line, 3);
    }

    #[test]
    fn doc_comments_are_flagged() {
        let lx = lex("/// docs here\n//! inner\n//// not doc\n// plain\nfn f() {}");
        let docs: Vec<bool> = lx.comments.iter().map(|cm| cm.doc).collect();
        assert_eq!(docs, vec![true, true, false, false]);
    }

    #[test]
    fn nested_block_comments_terminate() {
        let lx = lex("/* a /* nested */ b */ ident");
        assert_eq!(lx.comments.len(), 1);
        assert_eq!(lx.tokens.len(), 1);
        assert_eq!(lx.tokens[0].text, "ident");
    }

    #[test]
    fn strings_hide_their_contents() {
        let toks = kinds("let s = \"== 0.0 unsafe\"; let r = r#\"x != 0.0 \"quoted\" \"#;");
        let strs: Vec<&str> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Str)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(strs.len(), 2);
        assert!(strs[1].contains("\"quoted\""));
        for (k, t) in &toks {
            assert!(!(*k == TokKind::Punct && (t == "==" || t == "!=")), "{t}");
        }
        assert!(!toks.iter().any(|(k, t)| *k == TokKind::Ident && t == "unsafe"));
    }

    #[test]
    fn chars_vs_lifetimes() {
        let toks = kinds("let a: Vec<'x'> = f::<'a, 'static>('\\n', '\\'', 'b');");
        let chars: Vec<&str> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Char)
            .map(|(_, t)| t.as_str())
            .collect();
        let lifes: Vec<&str> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Lifetime)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(chars, vec!["x", "\\n", "\\'", "b"]);
        assert_eq!(lifes, vec!["'a", "'static"]);
    }

    #[test]
    fn float_vs_int_literals() {
        let toks = kinds("0.0 1e3 2. 1f32 0x1E 0b10 7 0..n 1.max(2) 3.5e-2 9usize");
        let floats: Vec<&str> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Float)
            .map(|(_, t)| t.as_str())
            .collect();
        let ints: Vec<&str> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Int)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(floats, vec!["0.0", "1e3", "2.", "1f32", "3.5e-2"]);
        assert_eq!(ints, vec!["0x1E", "0b10", "7", "0", "1", "2", "9usize"]);
    }

    #[test]
    fn multichar_puncts_and_lines() {
        let lx = lex("a == b\n c != d :: e = f");
        let got: Vec<(&str, usize)> = lx
            .tokens
            .iter()
            .filter(|t| t.kind == TokKind::Punct)
            .map(|t| (t.text.as_str(), t.line))
            .collect();
        assert_eq!(got, [("==", 1), ("!=", 2), ("::", 2), ("=", 2)]);
    }

    #[test]
    fn ge_le_do_not_fuse_into_eq() {
        // `>=` lexes as `>` then `=`; the float-eq lint only looks at
        // `==`/`!=` tokens, so no `==` token may appear here.
        let toks = kinds("if x >= 0.0 && y <= 1.0 {}");
        assert!(!toks.iter().any(|(_, t)| t == "=="));
    }
}
