//! Table 4: channel-selection strategies (S²FT-{R,W,A,S,G} x {Large,Small})
//! on commonsense + arithmetic.

use anyhow::Result;

use crate::data::{finetune_examples, ARITHMETIC, COMMONSENSE};
use crate::runtime::{open_backend, Executor};
use crate::train::GenModel;

use super::common::{evaluate_suite, finetune, pretrained_cached, save_result};
use crate::util::json::Json;

const MODEL: &str = "small";

pub fn run_tab4(artifacts: &str, quick: bool) -> Result<()> {
    let rt = open_backend(artifacts)?;
    let (pre_steps, ft_steps, n_eval) = if quick { (60, 30, 8) } else { (800, 120, 12) };
    let base = pretrained_cached(&rt, MODEL, pre_steps, 42)?;

    let strategies = [
        ("S2FT-R", "s2ft"),
        ("S2FT-W (L)", "s2ft-wL"),
        ("S2FT-W (S)", "s2ft-wS"),
        ("S2FT-A (L)", "s2ft-aL"),
        ("S2FT-A (S)", "s2ft-aS"),
        ("S2FT-S (L)", "s2ft-sL"),
        ("S2FT-S (S)", "s2ft-sS"),
        ("S2FT-G (L)", "s2ft-gL"),
        ("S2FT-G (S)", "s2ft-gS"),
    ];

    println!("\n=== Table 4: selection strategies (avg test acc %) ===");
    println!("{:<12} {:>12} {:>12}", "Strategy", "Commonsense", "Arithmetic");
    let filter = std::env::var("REPRO_METHODS").ok();
    let mut records = Vec::new();
    for (label, tag) in strategies {
        if filter.as_ref().is_some_and(|f| !f.split(',').any(|x| x.trim() == tag)) {
            continue;
        }
        if rt.artifacts().model(MODEL)?.methods.get(tag).is_none() {
            println!("  (skipping {label}: {tag} not built)");
            continue;
        }
        let mut accs = [0.0f64; 2];
        for (k, (suite, tasks)) in [
            ("commonsense", &COMMONSENSE[..]),
            ("arithmetic", &ARITHMETIC[..]),
        ]
        .iter()
        .enumerate()
        {
            let examples = finetune_examples(suite, 2000, 29);
            let trainer = finetune(&rt, MODEL, tag, &base, &examples, ft_steps, 31)?;
            let model = GenModel::new(&rt, MODEL, trainer.merged_params(&rt)?)?;
            let (_, avg) = evaluate_suite(&model, tasks, n_eval, 0x7AB4)?;
            accs[k] = avg;
        }
        println!("{:<12} {:>12.1} {:>12.1}", label, accs[0], accs[1]);
        records.push(Json::obj(vec![
            ("strategy", Json::str(label)),
            ("commonsense", Json::num(accs[0])),
            ("arithmetic", Json::num(accs[1])),
        ]));
    }
    println!("Expected shape (paper): random is a strong baseline; A/S-small ≥ R; G-large hurts.");
    // merge chunked invocations (keyed by strategy)
    let mut merged: Vec<Json> = Vec::new();
    if let Ok(prev) = std::fs::read_to_string("results/tab4.json") {
        if let Ok(Json::Arr(prows)) = Json::parse(&prev) {
            for pr in prows {
                let name = pr.get("strategy").ok().and_then(|v| v.as_str().ok().map(String::from));
                if let Some(name) = name {
                    let dup = records.iter().any(|r: &Json| {
                        r.get("strategy").ok().and_then(|v| v.as_str().ok())
                            == Some(name.as_str())
                    });
                    if !dup {
                        merged.push(pr);
                    }
                }
            }
        }
    }
    merged.extend(records);
    save_result("tab4", &Json::Arr(merged));
    Ok(())
}
