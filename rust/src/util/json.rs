//! Minimal JSON parser/serializer (the vendored crate set has no serde).
//!
//! Supports the full JSON grammar; numbers are f64. Used for meta.json,
//! run configs and machine-readable experiment results.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking for {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_f64()? as u64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.opt(key)
            .and_then(|v| v.as_str().ok().map(str::to_string))
            .unwrap_or_else(|| default.to_string())
    }

    pub fn num_or(&self, key: &str, default: f64) -> f64 {
        self.opt(key).and_then(|v| v.as_f64().ok()).unwrap_or(default)
    }

    // -- builders ------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(v: impl IntoIterator<Item = f64>) -> Json {
        Json::Arr(v.into_iter().map(Json::Num).collect())
    }

    pub fn arr_str(v: impl IntoIterator<Item = String>) -> Json {
        Json::Arr(v.into_iter().map(Json::Str).collect())
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    // -- serialization -------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}",
                  c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.i..self.i + 4)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?,
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // Re-walk multi-byte UTF-8 sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = utf8_width(c);
                        let s = std::str::from_utf8(&self.b[start..start + width])?;
                        out.push_str(s);
                        self.i = start + width;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected ',' or ']' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => bail!("expected ',' or '}}' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }
}

fn utf8_width(b: u8) -> usize {
    if b >= 0xF0 {
        4
    } else if b >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap().as_str().unwrap(), "c");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x":[1,2.5,"s",true,null],"y":{"z":-3}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""A\té héllo""#).unwrap();
        assert_eq!(v, Json::Str("A\té héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }
}
