//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! Require `make artifacts` (tiny model set). Each test compiles real HLO
//! through the xla crate and checks numerics end-to-end.

use std::collections::HashMap;

use repro::runtime::{Runtime, Tensor};

fn runtime() -> Runtime {
    Runtime::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")).expect("run `make artifacts`")
}

#[test]
fn init_forward_eval_roundtrip() {
    let rt = runtime();
    let init = rt.load("init_tiny").unwrap();
    let params = init.run(&[Tensor::scalar_i32(0)]).unwrap();
    assert_eq!(params.len(), init.spec.outputs.len());

    // Build the named pool of base params.
    let mut pool: HashMap<String, Tensor> = init
        .spec
        .outputs
        .iter()
        .map(|s| s.name.clone())
        .zip(params)
        .collect();
    let (b, t) = rt.artifacts.model("tiny").unwrap().default_batch();
    pool.insert("tokens".into(), Tensor::i32(vec![b, t], vec![1i32; b * t]));
    pool.insert("targets".into(), Tensor::i32(vec![b, t], vec![2i32; b * t]));
    pool.insert("loss_mask".into(), Tensor::f32(vec![b, t], vec![1.0; b * t]));

    let fwd = rt.load(&format!("fwd_tiny_{b}x{t}")).unwrap();
    let logits = fwd.run_named(&pool).unwrap();
    let lg = &logits["logits"];
    let vocab = rt.artifacts.model("tiny").unwrap().dims.vocab;
    assert_eq!(lg.shape, vec![b, t, vocab]);
    assert!(lg.as_f32().unwrap().iter().all(|x| x.is_finite()));

    let eval = rt.load(&format!("eval_tiny_{b}x{t}")).unwrap();
    let out = eval.run_named(&pool).unwrap();
    let loss = out["loss"].scalar_value_f32().unwrap();
    // Random init => loss near ln(vocab).
    let expect = (vocab as f32).ln();
    assert!(
        (loss - expect).abs() < 1.0,
        "loss {loss} too far from ln(vocab) {expect}"
    );
}

#[test]
fn executable_rejects_bad_inputs() {
    let rt = runtime();
    let init = rt.load("init_tiny").unwrap();
    // wrong arity
    assert!(init.run(&[]).is_err());
    // wrong shape
    let fwd_name = {
        let (b, t) = rt.artifacts.model("tiny").unwrap().default_batch();
        format!("fwd_tiny_{b}x{t}")
    };
    let fwd = rt.load(&fwd_name).unwrap();
    let bad: Vec<Tensor> = fwd.spec.inputs.iter().map(|_| Tensor::scalar_f32(0.0)).collect();
    assert!(fwd.run(&bad).is_err());
}

#[test]
fn executable_cache_returns_same_instance() {
    let rt = runtime();
    let a = rt.load("init_tiny").unwrap();
    let b = rt.load("init_tiny").unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
    rt.evict("init_tiny");
    let c = rt.load("init_tiny").unwrap();
    assert!(!std::sync::Arc::ptr_eq(&a, &c));
}

#[test]
fn init_is_deterministic_in_seed() {
    let rt = runtime();
    let init = rt.load("init_tiny").unwrap();
    let p1 = init.run(&[Tensor::scalar_i32(3)]).unwrap();
    let p2 = init.run(&[Tensor::scalar_i32(3)]).unwrap();
    let p3 = init.run(&[Tensor::scalar_i32(4)]).unwrap();
    assert_eq!(p1[0], p2[0]);
    // different seed differs somewhere
    let same = p1.iter().zip(&p3).all(|(a, b)| a == b);
    assert!(!same);
}
