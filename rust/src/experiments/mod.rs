//! Experiment harnesses — one per paper table/figure (DESIGN.md §6).
//!
//! Each harness prints the same row/column structure the paper reports and
//! writes machine-readable JSON under `results/`. Launch via
//! `repro experiment <id>`.

pub mod common;
mod fig2;
mod fig4;
mod fig5;
mod selection;
mod tables;
mod tab4;
mod tab5;
mod thm42;

pub use fig2::run_fig2;
pub use fig4::run_fig4;
pub use fig5::run_fig5;
pub use selection::run_selection;
pub use tab4::run_tab4;
pub use tab5::run_tab5;
pub use tables::{run_tab1, run_tab2, run_tab3};
pub use thm42::run_thm42;

use anyhow::{bail, Result};

/// Dispatch an experiment by id.
pub fn run(id: &str, artifacts: &str, quick: bool) -> Result<()> {
    match id {
        "fig2" => run_fig2(artifacts, quick),
        "tab1" => run_tab1(artifacts, quick),
        "tab2" => run_tab2(artifacts, quick),
        "tab3" => run_tab3(artifacts, quick),
        "fig4" => run_fig4(artifacts, quick),
        "tab4" => run_tab4(artifacts, quick),
        "fig5" => run_fig5(artifacts, quick),
        "tab5" => run_tab5(artifacts, quick),
        "thm42" => run_thm42(quick),
        "selection" => run_selection(artifacts, quick),
        "all" => {
            for id in ["thm42", "fig2", "tab1", "tab2", "tab3", "fig4", "tab4", "fig5", "tab5"] {
                println!("\n################ experiment {id} ################");
                run(id, artifacts, quick)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment {other:?} (try fig2|tab1|tab2|tab3|fig4|tab4|fig5|tab5|thm42|selection|all)"),
    }
}
