//! Multi-adapter serving (paper §6.2): router + dynamic batcher + engine
//! serving requests across many S²FT adapters with adapter-affinity
//! batching and scatter_add switches.
//!
//! Run: `cargo run --release --example multi_adapter_serving`
//! Env: ADAPTERS (default 6), REQUESTS (default 48), MAX_BATCH (default 8)

use anyhow::Result;

fn env(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let adapters = env("ADAPTERS", 6);
    let requests = env("REQUESTS", 48);
    let max_batch = env("MAX_BATCH", 8);
    println!("multi-adapter serving demo: {adapters} adapters, {requests} requests, max batch {max_batch}");
    repro::serve::demo("artifacts", "small", None, adapters, requests, max_batch)
}
