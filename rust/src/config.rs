//! Run configuration for the `repro` launcher (JSON files in `configs/`).

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Training/fine-tuning run description.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Model config name from meta.json (tiny | small | base).
    pub model: String,
    /// Method tag (fullft | lora | dora | spft | lisa | galore | s2ft | s2ft-pallas).
    pub method: String,
    /// Data source: "corpus" (LM pre-training), or a task suite
    /// ("arithmetic" | "commonsense" | "instruct").
    pub data: String,
    pub steps: usize,
    pub seed: u64,
    pub log_every: usize,
    pub artifacts: String,
    /// Optional checkpoint directory for the final merged weights.
    pub save_to: Option<String>,
    /// Optional base-layout checkpoint to start from (else the init
    /// artifact seeds fresh weights).
    pub init_from: Option<String>,
    /// Learning-rate warmup steps applied on the rust side via loss_mask
    /// scaling? No — lr is baked into the artifact; kept for bookkeeping.
    pub notes: String,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            model: "tiny".into(),
            method: "s2ft".into(),
            data: "corpus".into(),
            steps: 300,
            seed: 42,
            log_every: 10,
            artifacts: "artifacts".into(),
            save_to: None,
            init_from: None,
            notes: String::new(),
        }
    }
}

impl TrainConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        let d = TrainConfig::default();
        Ok(Self {
            model: j.get("model")?.as_str()?.to_string(),
            method: j.get("method")?.as_str()?.to_string(),
            data: j.str_or("data", &d.data),
            steps: j.num_or("steps", d.steps as f64) as usize,
            seed: j.num_or("seed", d.seed as f64) as u64,
            log_every: j.num_or("log_every", d.log_every as f64) as usize,
            artifacts: j.str_or("artifacts", &d.artifacts),
            save_to: j.opt("save_to").and_then(|v| v.as_str().ok()).map(String::from),
            init_from: j.opt("init_from").and_then(|v| v.as_str().ok()).map(String::from),
            notes: j.str_or("notes", ""),
        })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        Self::from_json(&Json::parse(&text)?)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(self.model.clone())),
            ("method", Json::str(self.method.clone())),
            ("data", Json::str(self.data.clone())),
            ("steps", Json::num(self.steps as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("log_every", Json::num(self.log_every as f64)),
            ("artifacts", Json::str(self.artifacts.clone())),
        ])
    }
}

/// Serving run description.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub model: String,
    pub artifacts: String,
    /// Base-layout weights checkpoint directory.
    pub weights: Option<String>,
    /// Max requests batched per engine iteration.
    pub max_batch: usize,
    /// Batching window.
    pub window_ms: u64,
    /// Max new tokens per request.
    pub max_new_tokens: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            model: "small".into(),
            artifacts: "artifacts".into(),
            weights: None,
            max_batch: 8,
            window_ms: 5,
            max_new_tokens: 8,
        }
    }
}

impl ServeConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        let d = ServeConfig::default();
        Ok(Self {
            model: j.str_or("model", &d.model),
            artifacts: j.str_or("artifacts", &d.artifacts),
            weights: j.opt("weights").and_then(|v| v.as_str().ok()).map(String::from),
            max_batch: j.num_or("max_batch", d.max_batch as f64) as usize,
            window_ms: j.num_or("window_ms", d.window_ms as f64) as u64,
            max_new_tokens: j.num_or("max_new_tokens", d.max_new_tokens as f64) as usize,
        })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        Self::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn train_config_defaults() {
        let j = Json::parse(r#"{"model":"tiny","method":"s2ft"}"#).unwrap();
        let c = TrainConfig::from_json(&j).unwrap();
        assert_eq!(c.steps, 300);
        assert_eq!(c.seed, 42);
        assert_eq!(c.artifacts, "artifacts");
        assert_eq!(c.data, "corpus");
    }

    #[test]
    fn train_config_roundtrip() {
        let j = Json::parse(r#"{"model":"small","method":"lora","steps":10,"seed":1}"#).unwrap();
        let c = TrainConfig::from_json(&j).unwrap();
        let c2 = TrainConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.model, "small");
        assert_eq!(c2.steps, 10);
    }

    #[test]
    fn serve_config_defaults() {
        let j = Json::parse(r#"{"model":"small"}"#).unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.max_batch, 8);
        assert_eq!(c.window_ms, 5);
    }
}
