//! Evaluation: batched loss via the eval artifact, and exact-match task
//! accuracy via greedy decoding with the base-layout forward artifact.

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::data::batch::{encode_prompt, supervised_batch};
use crate::data::tokenizer::{Tokenizer, EOS, PAD};
use crate::data::{Batch, Example};
use crate::runtime::{Executable, Executor, Tensor};

/// A merged (base-layout) model ready for forward passes.
pub struct GenModel {
    pub model: String,
    pub b: usize,
    pub t: usize,
    fwd: std::sync::Arc<dyn Executable>,
    eval: std::sync::Arc<dyn Executable>,
    pub params: HashMap<String, Tensor>,
    vocab: usize,
}

impl GenModel {
    pub fn new(rt: &dyn Executor, model: &str, params: HashMap<String, Tensor>) -> Result<Self> {
        let mm = rt.artifacts().model(model)?;
        let (b, t) = mm.default_batch();
        let fwd = rt
            .load(&format!("fwd_{model}_{b}x{t}"))
            .context("forward artifact")?;
        let eval = rt
            .load(&format!("eval_{model}_{b}x{t}"))
            .context("eval artifact")?;
        Ok(Self { model: model.to_string(), b, t, fwd, eval, params, vocab: mm.dims.vocab })
    }

    /// Masked LM loss + token accuracy on one batch.
    pub fn eval_batch(&self, batch: &Batch) -> Result<(f32, f32)> {
        let mut pool = self.params.clone();
        pool.insert("tokens".into(), batch.tokens.clone());
        pool.insert("targets".into(), batch.targets.clone());
        pool.insert("loss_mask".into(), batch.loss_mask.clone());
        let out = self.eval.run_named(&pool)?;
        let loss = out["loss"].scalar_value_f32()?;
        let denom = batch.answer_tokens().max(1) as f32;
        let acc = out["ncorrect"].scalar_value_f32()? / denom;
        Ok((loss, acc))
    }

    /// Greedy-decode up to `max_new` tokens for up to `b` prompts at once.
    ///
    /// The forward artifact has a fixed (b, t) shape, so decoding is
    /// recompute-per-token; prompts and answers are short so this stays
    /// cheap (answers ≤ 12 bytes).
    pub fn generate(&self, prompts: &[String], max_new: usize) -> Result<Vec<String>> {
        let tk = Tokenizer;
        let mut results = Vec::with_capacity(prompts.len());
        for chunk in prompts.chunks(self.b) {
            let mut rows: Vec<Vec<i32>> = Vec::with_capacity(self.b);
            let mut pos: Vec<usize> = Vec::with_capacity(self.b);
            let mut done: Vec<bool> = Vec::with_capacity(self.b);
            for i in 0..self.b {
                let p = chunk.get(i).map(|s| s.as_str()).unwrap_or("");
                let (toks, gp) = encode_prompt(&tk, p, self.t);
                rows.push(toks);
                pos.push(gp.min(self.t - 1));
                done.push(i >= chunk.len());
            }
            for _ in 0..max_new {
                if done.iter().all(|&d| d) {
                    break;
                }
                let flat: Vec<i32> = rows.iter().flatten().copied().collect();
                let mut pool = self.params.clone();
                pool.insert("tokens".into(), Tensor::i32(vec![self.b, self.t], flat));
                let out = self.fwd.run_named(&pool)?;
                let logits = out["logits"].as_f32()?.to_vec();
                for i in 0..self.b {
                    if done[i] || pos[i] >= self.t {
                        done[i] = true;
                        continue;
                    }
                    // next-token distribution at position pos-1
                    let row_off = (i * self.t + pos[i] - 1) * self.vocab;
                    let slice = &logits[row_off..row_off + self.vocab];
                    let arg = argmax(slice) as i32;
                    if arg == EOS || arg == PAD {
                        done[i] = true;
                        continue;
                    }
                    rows[i][pos[i]] = arg;
                    pos[i] += 1;
                }
            }
            for (i, row) in rows.iter().enumerate().take(chunk.len()) {
                let (_, gp) = encode_prompt(&tk, &chunk[i], self.t);
                results.push(tk.decode_until_eos(&row[gp..pos[i].max(gp)]));
            }
        }
        Ok(results)
    }
}

fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

/// Exact-match accuracy of greedy generations against the gold answers.
pub fn task_accuracy(model: &GenModel, examples: &[Example]) -> Result<f64> {
    let prompts: Vec<String> = examples.iter().map(|e| e.prompt.clone()).collect();
    let max_new = examples.iter().map(|e| e.answer.len() + 1).max().unwrap_or(8);
    let outs = model.generate(&prompts, max_new)?;
    let correct = outs
        .iter()
        .zip(examples)
        .filter(|(got, ex)| got.trim() == ex.answer)
        .count();
    Ok(correct as f64 / examples.len().max(1) as f64)
}

/// Mean supervised loss of a model over examples (memorization metric).
pub fn eval_loss(model: &GenModel, examples: &[Example]) -> Result<f32> {
    let tk = Tokenizer;
    let mut total = 0.0f64;
    let mut batches = 0usize;
    for chunk in examples.chunks(model.b) {
        let batch = supervised_batch(&tk, chunk, model.b, model.t);
        let (loss, _) = model.eval_batch(&batch)?;
        total += loss as f64;
        batches += 1;
    }
    Ok((total / batches.max(1) as f64) as f32)
}
